//! Discrete-event simulation core (substrate).
//!
//! The paper's evaluation runs on a 48-node NPU production cluster; this
//! module provides the virtual-time machinery that lets us reproduce the
//! *scheduling behaviour* of that cluster (queueing, overlap, load
//! balancing, resource binding) deterministically on one CPU. The MARL
//! engine (`orchestrator::simloop`) and the paper benches drive it.
//!
//! Two interchangeable queue backends produce **bit-identical** pop
//! sequences (verified by property and integration tests):
//!  * [`QueueKind::BinaryHeap`] — `std::collections::BinaryHeap`,
//!    O(log n) push/pop, the reference implementation and fallback;
//!  * [`QueueKind::Calendar`] — a bucketed calendar queue (Brown 1988),
//!    amortized O(1) push/pop under the simloop's dense near-future
//!    event pattern; buckets re-grid adaptively on load and when the
//!    active window drains.
//!
//! # Time invariant
//!
//! Event times must be finite. A NaN would silently corrupt heap order
//! (`partial_cmp(..).unwrap_or(Equal)` treats it as equal to
//! everything), and both NaN and ±inf misfile calendar buckets.
//! `push_at` rejects non-finite times with a debug assertion; callers
//! must keep virtual-time arithmetic NaN-free (`0.0 * inf`,
//! `inf - inf`, `0.0 / 0.0` are the usual sources).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Virtual time in seconds.
pub type Time = f64;

/// Event-queue backend selection (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QueueKind {
    /// Binary-heap reference backend.
    #[default]
    BinaryHeap,
    /// Bucketed calendar queue — O(1) amortized for dense near-future
    /// event patterns.
    Calendar,
}

/// Min event queue with FIFO tie-breaking (stable, deterministic).
#[derive(Debug)]
pub struct EventQueue<E> {
    backend: Backend<E>,
    seq: u64,
    now: Time,
}

#[derive(Debug)]
enum Backend<E> {
    Heap(BinaryHeap<Entry<E>>),
    Calendar(Calendar<E>),
}

#[derive(Debug)]
struct Entry<E> {
    time: Time,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed for min-heap; ties broken by insertion order.
        // Times are never NaN (module invariant), so partial_cmp is
        // total here.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

// ---------------------------------------------------------------------------
// Calendar backend
// ---------------------------------------------------------------------------

const CAL_INITIAL_BUCKETS: usize = 64;
const CAL_MAX_BUCKETS: usize = 1 << 16;
/// Re-grid when the in-window population exceeds this per-bucket load.
const CAL_MAX_LOAD: usize = 4;
/// Buckets bigger than this (same-timestamp storms that re-gridding
/// cannot split) are sorted once and popped from the tail, keeping the
/// drain O(b log b) instead of O(b²) min-scans.
const CAL_SORT_THRESHOLD: usize = 32;

#[derive(Debug)]
struct Calendar<E> {
    /// Unsorted buckets; pop scans the current bucket for the (time,
    /// seq) minimum. Bucket populations stay O(1) via re-gridding.
    buckets: Vec<Vec<Entry<E>>>,
    /// Time of bucket 0's lower edge.
    origin: Time,
    width: f64,
    /// First possibly-non-empty bucket (monotone within a window:
    /// pushes always land at or after the bucket of `now`).
    cur: usize,
    in_window: usize,
    /// Events at or beyond the window end, unsorted.
    overflow: Vec<Entry<E>>,
    /// Whether `buckets[cur]` is currently sorted descending by
    /// (time, seq) — min at the tail, popped O(1).
    cur_sorted: bool,
    len: usize,
}

impl<E> Calendar<E> {
    fn new() -> Self {
        Calendar {
            buckets: (0..CAL_INITIAL_BUCKETS).map(|_| Vec::new()).collect(),
            origin: 0.0,
            width: 1.0,
            cur: 0,
            in_window: 0,
            overflow: Vec::new(),
            cur_sorted: false,
            len: 0,
        }
    }

    fn window_end(&self) -> Time {
        self.origin + self.width * self.buckets.len() as f64
    }

    fn push(&mut self, e: Entry<E>) {
        self.push_inner(e, true);
    }

    fn push_inner(&mut self, e: Entry<E>, allow_regrid: bool) {
        self.len += 1;
        if e.time < self.window_end() {
            // A time below bucket `cur`'s edge (possible when the grid
            // origin sits ahead of `now`) files into the frontier
            // bucket: it is scanned first, so ordering is preserved —
            // every event in a later bucket has a strictly later edge.
            // `as usize` saturates negative values to 0.
            let idx = (((e.time - self.origin) / self.width) as usize)
                .min(self.buckets.len() - 1)
                .max(self.cur);
            if idx == self.cur && self.cur_sorted {
                // Keep the drained-from bucket sorted (descending).
                let k = (e.time, e.seq);
                let pos = self.buckets[idx].partition_point(|x| (x.time, x.seq) > k);
                self.buckets[idx].insert(pos, e);
            } else {
                self.buckets[idx].push(e);
            }
            self.in_window += 1;
            // Growth re-grid — but only while the grid can still grow:
            // at CAL_MAX_BUCKETS re-gridding cannot reduce per-bucket
            // load, and triggering it on every push would make pushes
            // O(n). Past the cap, load per bucket simply grows.
            if allow_regrid
                && self.buckets.len() < CAL_MAX_BUCKETS
                && self.in_window > self.buckets.len() * CAL_MAX_LOAD
            {
                self.regrid();
            }
        } else {
            self.overflow.push(e);
        }
    }

    fn pop(&mut self) -> Option<Entry<E>> {
        if self.len == 0 {
            return None;
        }
        loop {
            while self.cur < self.buckets.len() && self.buckets[self.cur].is_empty() {
                self.cur += 1;
                self.cur_sorted = false;
            }
            if self.cur == self.buckets.len() {
                // Window drained — re-grid around the remaining events.
                debug_assert!(!self.overflow.is_empty());
                self.regrid();
                continue;
            }
            let b = &mut self.buckets[self.cur];
            let e = if self.cur_sorted {
                b.pop().expect("non-empty sorted bucket")
            } else if b.len() > CAL_SORT_THRESHOLD {
                // Same-timestamp storm re-gridding can't split: sort
                // once (descending), then pop the min from the tail.
                b.sort_unstable_by(|a, b2| {
                    (b2.time, b2.seq)
                        .partial_cmp(&(a.time, a.seq))
                        .expect("finite event times")
                });
                self.cur_sorted = true;
                b.pop().expect("non-empty bucket")
            } else {
                let mut mi = 0;
                for i in 1..b.len() {
                    if (b[i].time, b[i].seq) < (b[mi].time, b[mi].seq) {
                        mi = i;
                    }
                }
                b.swap_remove(mi)
            };
            self.in_window -= 1;
            self.len -= 1;
            return Some(e);
        }
    }

    /// Rebuild the grid around the current population: origin at the
    /// earliest event, bucket count ~ population, width ~ span /
    /// buckets. All events (window + overflow) are redistributed; the
    /// new window always covers the latest event, so `overflow` only
    /// repopulates through later far-future pushes. Amortized O(1) per
    /// event: growth re-grids double the bucket count, drain re-grids
    /// touch each event once per window advance.
    fn regrid(&mut self) {
        let mut all: Vec<Entry<E>> = Vec::with_capacity(self.len);
        for b in &mut self.buckets {
            all.append(b);
        }
        all.append(&mut self.overflow);
        debug_assert_eq!(all.len(), self.len);
        debug_assert!(!all.is_empty());
        let mut min_t = f64::INFINITY;
        let mut max_t = f64::NEG_INFINITY;
        for e in &all {
            min_t = min_t.min(e.time);
            max_t = max_t.max(e.time);
        }
        let n = all.len().max(1);
        let nb = n
            .next_power_of_two()
            .clamp(CAL_INITIAL_BUCKETS, CAL_MAX_BUCKETS);
        let span = max_t - min_t;
        let width = if span > 0.0 { span * 1.25 / nb as f64 } else { 1.0 };
        self.origin = min_t;
        self.width = width;
        if self.buckets.len() != nb {
            self.buckets.resize_with(nb, Vec::new);
        }
        self.cur = 0;
        self.cur_sorted = false;
        self.in_window = 0;
        self.len = 0;
        for e in all {
            self.push_inner(e, false);
        }
    }
}

// ---------------------------------------------------------------------------
// EventQueue facade
// ---------------------------------------------------------------------------

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    /// Heap-backed queue (the reference backend).
    pub fn new() -> Self {
        Self::with_kind(QueueKind::BinaryHeap)
    }

    pub fn with_kind(kind: QueueKind) -> Self {
        let backend = match kind {
            QueueKind::BinaryHeap => Backend::Heap(BinaryHeap::new()),
            QueueKind::Calendar => Backend::Calendar(Calendar::new()),
        };
        EventQueue {
            backend,
            seq: 0,
            now: 0.0,
        }
    }

    pub fn kind(&self) -> QueueKind {
        match self.backend {
            Backend::Heap(_) => QueueKind::BinaryHeap,
            Backend::Calendar(_) => QueueKind::Calendar,
        }
    }

    pub fn now(&self) -> Time {
        self.now
    }

    /// Schedule `payload` at absolute time `t` (clamped to now).
    ///
    /// `t` must be finite — never NaN (see the module-level time
    /// invariant); an infinite time would additionally break calendar
    /// bucket indexing.
    pub fn push_at(&mut self, t: Time, payload: E) {
        debug_assert!(t.is_finite(), "non-finite event time {t} would corrupt queue order");
        let time = if t < self.now { self.now } else { t };
        let entry = Entry {
            time,
            seq: self.seq,
            payload,
        };
        self.seq += 1;
        match &mut self.backend {
            Backend::Heap(h) => h.push(entry),
            Backend::Calendar(c) => c.push(entry),
        }
    }

    /// Schedule after a delay.
    pub fn push_in(&mut self, dt: Time, payload: E) {
        debug_assert!(dt >= 0.0, "negative delay {dt}");
        self.push_at(self.now + dt, payload);
    }

    /// Pop the next event, advancing the clock.
    pub fn pop(&mut self) -> Option<(Time, E)> {
        let e = match &mut self.backend {
            Backend::Heap(h) => h.pop(),
            Backend::Calendar(c) => c.pop(),
        };
        e.map(|e| {
            debug_assert!(e.time >= self.now);
            self.now = e.time;
            (e.time, e.payload)
        })
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn len(&self) -> usize {
        match &self.backend {
            Backend::Heap(h) => h.len(),
            Backend::Calendar(c) => c.len,
        }
    }

    // ---- checkpointing (DESIGN.md §12) ------------------------------------

    /// Complete queue state for a checkpoint: `(now, next_seq, entries)`
    /// with entries sorted by `(time, seq)` — the exact future pop
    /// order. The snapshot is **backend-agnostic**: heap internals and
    /// calendar bucket geometry are derived structure, so a snapshot
    /// taken from one backend restores into either and pops the same
    /// sequence bit-for-bit (which is why the backend choice is not
    /// part of the checkpoint's config fingerprint).
    pub fn snapshot_entries(&self) -> (Time, u64, Vec<(Time, u64, &E)>) {
        let mut entries: Vec<(Time, u64, &E)> = match &self.backend {
            Backend::Heap(h) => h.iter().map(|e| (e.time, e.seq, &e.payload)).collect(),
            Backend::Calendar(c) => c
                .buckets
                .iter()
                .flatten()
                .chain(c.overflow.iter())
                .map(|e| (e.time, e.seq, &e.payload))
                .collect(),
        };
        entries.sort_by(|a, b| {
            a.0.partial_cmp(&b.0)
                .expect("finite event times")
                .then(a.1.cmp(&b.1))
        });
        (self.now, self.seq, entries)
    }

    /// Rebuild a queue from a [`EventQueue::snapshot_entries`] capture.
    /// Entries keep their original FIFO sequence numbers, so ties at
    /// equal times break exactly as they would have in the original
    /// run; `next_seq` continues the counter so post-restore pushes
    /// sort after every pre-snapshot event at the same time.
    pub fn restore(
        kind: QueueKind,
        now: Time,
        next_seq: u64,
        entries: Vec<(Time, u64, E)>,
    ) -> Self {
        let mut q = Self::with_kind(kind);
        q.now = now;
        for (time, seq, payload) in entries {
            debug_assert!(time.is_finite() && time >= now && seq < next_seq);
            let entry = Entry { time, seq, payload };
            match &mut q.backend {
                Backend::Heap(h) => h.push(entry),
                Backend::Calendar(c) => c.push(entry),
            }
        }
        q.seq = next_seq;
        q
    }
}

/// Accumulates busy device-seconds over a set of devices — the hardware
/// utilization metric of RQ3 ("percentage of time AI cores remain active").
#[derive(Debug, Clone, Default)]
pub struct BusyTracker {
    busy_device_seconds: f64,
    /// (time, devices_busy) step series for Fig. 10 style plots.
    series: Vec<(Time, usize)>,
}

impl BusyTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record `n_devices` busy for `duration` seconds starting at `t`.
    pub fn add_busy(&mut self, n_devices: usize, duration: Time) {
        self.busy_device_seconds += n_devices as f64 * duration;
    }

    pub fn mark(&mut self, t: Time, busy_now: usize) {
        if self.series.last().map(|&(_, b)| b) != Some(busy_now) {
            self.series.push((t, busy_now));
        }
    }

    pub fn busy_device_seconds(&self) -> f64 {
        self.busy_device_seconds
    }

    /// Average utilization over [0, horizon] for a pool of `total` devices.
    pub fn utilization(&self, total_devices: usize, horizon: Time) -> f64 {
        if total_devices == 0 || horizon <= 0.0 {
            return 0.0;
        }
        (self.busy_device_seconds / (total_devices as f64 * horizon)).min(1.0)
    }

    /// Utilization time-series with the given sample period, computed
    /// from the step series (Fig. 10).
    pub fn series(&self) -> &[(Time, usize)] {
        &self.series
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    fn both_kinds() -> [QueueKind; 2] {
        [QueueKind::BinaryHeap, QueueKind::Calendar]
    }

    #[test]
    fn events_pop_in_time_order() {
        for kind in both_kinds() {
            let mut q = EventQueue::with_kind(kind);
            q.push_at(3.0, "c");
            q.push_at(1.0, "a");
            q.push_at(2.0, "b");
            let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
            assert_eq!(order, vec!["a", "b", "c"], "{kind:?}");
        }
    }

    #[test]
    fn ties_are_fifo() {
        for kind in both_kinds() {
            let mut q = EventQueue::with_kind(kind);
            for i in 0..10 {
                q.push_at(5.0, i);
            }
            let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
            assert_eq!(order, (0..10).collect::<Vec<_>>(), "{kind:?}");
        }
    }

    #[test]
    fn clock_advances_monotonically() {
        for kind in both_kinds() {
            let mut q = EventQueue::with_kind(kind);
            q.push_at(2.0, ());
            q.push_at(1.0, ());
            let (t1, _) = q.pop().unwrap();
            // Past-time push clamps to now.
            q.push_at(0.5, ());
            let (t2, _) = q.pop().unwrap();
            let (t3, _) = q.pop().unwrap();
            assert_eq!(t1, 1.0);
            assert_eq!(t2, 1.0);
            assert_eq!(t3, 2.0);
            assert_eq!(q.now(), 2.0);
        }
    }

    #[test]
    fn push_in_uses_current_time() {
        for kind in both_kinds() {
            let mut q = EventQueue::with_kind(kind);
            q.push_at(10.0, "first");
            q.pop();
            q.push_in(5.0, "second");
            let (t, _) = q.pop().unwrap();
            assert_eq!(t, 15.0);
        }
    }

    #[test]
    fn calendar_survives_bursts_and_jumps() {
        // Growth re-grid (burst), window-advance re-grid (drain), and
        // far-future overflow all on one queue.
        let mut q = EventQueue::with_kind(QueueKind::Calendar);
        for i in 0..2000u64 {
            q.push_at(1.0 + (i % 7) as f64 * 1e-3, i);
        }
        q.push_at(1e6, 999_999);
        let mut last = -1.0;
        let mut n = 0;
        while let Some((t, _)) = q.pop() {
            assert!(t >= last);
            last = t;
            n += 1;
        }
        assert_eq!(n, 2001);
        assert_eq!(last, 1e6);
    }

    #[test]
    fn prop_calendar_matches_heap_exactly() {
        forall("calendar pops == heap pops", 120, |rng| {
            let mut heap = EventQueue::with_kind(QueueKind::BinaryHeap);
            let mut cal = EventQueue::with_kind(QueueKind::Calendar);
            let mut next_id = 0u64;
            for _ in 0..400 {
                if rng.f64() < 0.6 {
                    // Mix of dense near-future, exact ties, and
                    // far-future outliers.
                    let t = match rng.below(10) {
                        0 => heap.now(),                        // tie with now
                        1 => heap.now() + 1000.0 * rng.f64(),   // far future
                        2 => heap.now() - rng.f64(),            // past → clamp
                        _ => heap.now() + rng.f64() * 3.0,      // dense
                    };
                    heap.push_at(t, next_id);
                    cal.push_at(t, next_id);
                    next_id += 1;
                } else {
                    let a = heap.pop();
                    let b = cal.pop();
                    match (a, b) {
                        (None, None) => {}
                        (Some((ta, ea)), Some((tb, eb))) => {
                            assert_eq!(ta, tb, "time diverged");
                            assert_eq!(ea, eb, "order diverged");
                        }
                        other => panic!("length diverged: {other:?}"),
                    }
                    assert_eq!(heap.now(), cal.now());
                    assert_eq!(heap.len(), cal.len());
                }
            }
            // Drain both completely.
            loop {
                match (heap.pop(), cal.pop()) {
                    (None, None) => break,
                    (Some((ta, ea)), Some((tb, eb))) => {
                        assert_eq!((ta, ea), (tb, eb));
                    }
                    other => panic!("length diverged: {other:?}"),
                }
            }
        });
    }

    /// Satellite (ISSUE 8): snapshot/restore preserves pop order
    /// bit-identically for both backends, at any split point, with
    /// FIFO ties and post-restore pushes in the mix.
    #[test]
    fn prop_snapshot_restore_pop_order_bit_identical() {
        for kind in both_kinds() {
            forall("snapshot/restore pops == uninterrupted pops", 80, |rng| {
                let mut q = EventQueue::with_kind(kind);
                let mut reference = EventQueue::with_kind(kind);
                let mut next_id = 0u64;
                let mut push = |q: &mut EventQueue<u64>, r: &mut EventQueue<u64>, rng: &mut crate::util::rng::Pcg64, id: &mut u64| {
                    let t = match rng.below(8) {
                        0 => q.now(),                      // exact tie
                        1 => q.now() + 500.0 * rng.f64(),  // far future
                        _ => q.now() + rng.f64() * 2.0,    // dense
                    };
                    q.push_at(t, *id);
                    r.push_at(t, *id);
                    *id += 1;
                };
                for _ in 0..120 {
                    if rng.f64() < 0.7 {
                        push(&mut q, &mut reference, rng, &mut next_id);
                    } else {
                        assert_eq!(q.pop(), reference.pop());
                    }
                }
                // Snapshot mid-run, rebuild, and verify the restored
                // queue continues exactly like the uninterrupted one —
                // including events pushed *after* the restore.
                let (now, next_seq, entries) = q.snapshot_entries();
                let owned: Vec<(Time, u64, u64)> =
                    entries.iter().map(|&(t, s, p)| (t, s, *p)).collect();
                let mut restored = EventQueue::restore(kind, now, next_seq, owned);
                assert_eq!(restored.now(), reference.now());
                assert_eq!(restored.len(), reference.len());
                for _ in 0..40 {
                    if rng.f64() < 0.4 {
                        push(&mut restored, &mut reference, rng, &mut next_id);
                    } else {
                        assert_eq!(restored.pop(), reference.pop());
                    }
                }
                loop {
                    match (restored.pop(), reference.pop()) {
                        (None, None) => break,
                        (a, b) => assert_eq!(a, b, "{kind:?} diverged"),
                    }
                }
            });
        }
    }

    /// A snapshot taken on one backend restores into the *other* and
    /// still pops identically — the capture is backend-agnostic, which
    /// is why `--event-queue` is excluded from the checkpoint's config
    /// fingerprint.
    #[test]
    fn snapshot_restores_across_backends() {
        let mut cal = EventQueue::with_kind(QueueKind::Calendar);
        for i in 0..300u64 {
            cal.push_at(1.0 + (i % 11) as f64 * 0.25, i);
        }
        cal.push_at(1e5, 9999);
        for _ in 0..50 {
            cal.pop();
        }
        let (now, next_seq, entries) = cal.snapshot_entries();
        let owned: Vec<(Time, u64, u64)> = entries.iter().map(|&(t, s, p)| (t, s, *p)).collect();
        let mut heap = EventQueue::restore(QueueKind::BinaryHeap, now, next_seq, owned);
        assert_eq!(heap.kind(), QueueKind::BinaryHeap);
        loop {
            match (heap.pop(), cal.pop()) {
                (None, None) => break,
                (a, b) => assert_eq!(a, b, "cross-backend restore diverged"),
            }
        }
        assert_eq!(heap.now(), cal.now());
    }

    #[test]
    fn busy_tracker_utilization() {
        let mut b = BusyTracker::new();
        b.add_busy(4, 10.0); // 40 device-seconds
        assert!((b.utilization(8, 10.0) - 0.5).abs() < 1e-12);
        assert!((b.utilization(8, 20.0) - 0.25).abs() < 1e-12);
        assert_eq!(b.utilization(0, 10.0), 0.0);
    }
}
