//! Discrete-event simulation core (substrate).
//!
//! The paper's evaluation runs on a 48-node NPU production cluster; this
//! module provides the virtual-time machinery that lets us reproduce the
//! *scheduling behaviour* of that cluster (queueing, overlap, load
//! balancing, resource binding) deterministically on one CPU. The MARL
//! engine (`orchestrator::simloop`) and the paper benches drive it.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Virtual time in seconds.
pub type Time = f64;

/// Min-heap event queue with FIFO tie-breaking (stable, deterministic).
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: Time,
}

#[derive(Debug)]
struct Entry<E> {
    time: Time,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed for min-heap; ties broken by insertion order.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0.0,
        }
    }

    pub fn now(&self) -> Time {
        self.now
    }

    /// Schedule `payload` at absolute time `t` (clamped to now).
    pub fn push_at(&mut self, t: Time, payload: E) {
        let time = if t < self.now { self.now } else { t };
        self.heap.push(Entry {
            time,
            seq: self.seq,
            payload,
        });
        self.seq += 1;
    }

    /// Schedule after a delay.
    pub fn push_in(&mut self, dt: Time, payload: E) {
        debug_assert!(dt >= 0.0, "negative delay {dt}");
        self.push_at(self.now + dt, payload);
    }

    /// Pop the next event, advancing the clock.
    pub fn pop(&mut self) -> Option<(Time, E)> {
        self.heap.pop().map(|e| {
            debug_assert!(e.time >= self.now);
            self.now = e.time;
            (e.time, e.payload)
        })
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

/// Accumulates busy device-seconds over a set of devices — the hardware
/// utilization metric of RQ3 ("percentage of time AI cores remain active").
#[derive(Debug, Clone, Default)]
pub struct BusyTracker {
    busy_device_seconds: f64,
    /// (time, devices_busy) step series for Fig. 10 style plots.
    series: Vec<(Time, usize)>,
    current_busy: usize,
}

impl BusyTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record `n_devices` busy for `duration` seconds starting at `t`.
    pub fn add_busy(&mut self, n_devices: usize, duration: Time) {
        self.busy_device_seconds += n_devices as f64 * duration;
    }

    pub fn mark(&mut self, t: Time, busy_now: usize) {
        if self.series.last().map(|&(_, b)| b) != Some(busy_now) {
            self.series.push((t, busy_now));
        }
        self.current_busy = busy_now;
    }

    pub fn busy_device_seconds(&self) -> f64 {
        self.busy_device_seconds
    }

    /// Average utilization over [0, horizon] for a pool of `total` devices.
    pub fn utilization(&self, total_devices: usize, horizon: Time) -> f64 {
        if total_devices == 0 || horizon <= 0.0 {
            return 0.0;
        }
        (self.busy_device_seconds / (total_devices as f64 * horizon)).min(1.0)
    }

    /// Utilization time-series with the given sample period, computed
    /// from the step series (Fig. 10).
    pub fn series(&self) -> &[(Time, usize)] {
        &self.series
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_pop_in_time_order() {
        let mut q = EventQueue::new();
        q.push_at(3.0, "c");
        q.push_at(1.0, "a");
        q.push_at(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_are_fifo() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.push_at(5.0, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.push_at(2.0, ());
        q.push_at(1.0, ());
        let (t1, _) = q.pop().unwrap();
        // Past-time push clamps to now.
        q.push_at(0.5, ());
        let (t2, _) = q.pop().unwrap();
        let (t3, _) = q.pop().unwrap();
        assert_eq!(t1, 1.0);
        assert_eq!(t2, 1.0);
        assert_eq!(t3, 2.0);
        assert_eq!(q.now(), 2.0);
    }

    #[test]
    fn push_in_uses_current_time() {
        let mut q = EventQueue::new();
        q.push_at(10.0, "first");
        q.pop();
        q.push_in(5.0, "second");
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 15.0);
    }

    #[test]
    fn busy_tracker_utilization() {
        let mut b = BusyTracker::new();
        b.add_busy(4, 10.0); // 40 device-seconds
        assert!((b.utilization(8, 10.0) - 0.5).abs() < 1e-12);
        assert!((b.utilization(8, 20.0) - 0.25).abs() < 1e-12);
        assert_eq!(b.utilization(0, 10.0), 0.0);
    }
}
