//! Training-state swap (§6.2, Fig. 6): move weights + optimizer states
//! between device and host memory through the Set/Get API when process
//! groups are destroyed/re-created.
//!
//! Cost model (validated against Fig. 11's measurements in
//! `benches`): per-group states are ZeRO-3 sharded, every device
//! offloads its shard over the host link in parallel (the link is shared
//! by the devices of one node, so effective per-shard bandwidth divides
//! by the node's concurrently-offloading devices); suspend/resume of the
//! process group itself is a near-constant control-plane cost.

use crate::config::{ClusterConfig, ModelScale};
use crate::memstore::{Location, MemStore, TransferModel};

/// Control-plane constants (Fig. 11: suspend/resume "minimal and nearly
/// constant regardless of model scale").
pub const SUSPEND_S: f64 = 0.35;
pub const RESUME_S: f64 = 0.55;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SwapCost {
    /// Process-group control-plane (suspend or resume).
    pub control_s: f64,
    /// Data movement (offload or onload).
    pub transfer_s: f64,
}

impl SwapCost {
    pub fn total(&self) -> f64 {
        self.control_s + self.transfer_s
    }
}

fn shard_transfer_s(model: ModelScale, cfg: &ClusterConfig, bw: f64) -> f64 {
    let group = model.train_group_devices() as f64;
    let shard_bytes = model.train_state_bytes() / group;
    // Every device has a dedicated host link (`bw`), but concurrent
    // offloads on one node contend for host memory bandwidth. A group
    // spans ceil(group/devices_per_node) nodes.
    let nodes = (group / cfg.devices_per_node as f64).ceil();
    let devices_per_node_in_group = group / nodes;
    let eff_bw = bw.min(cfg.host_mem_bw / devices_per_node_in_group);
    shard_bytes / eff_bw + cfg.control_op_s
}

/// Swap-out = suspend the process group + offload states D2H.
pub fn swap_out_cost(model: ModelScale, cfg: &ClusterConfig) -> SwapCost {
    SwapCost {
        control_s: SUSPEND_S,
        transfer_s: shard_transfer_s(model, cfg, cfg.h2d_bw),
    }
}

/// Swap-in = re-create the process group + onload states.
/// `local` = resumed on the node holding the checkpoint (H2D); otherwise
/// the RH2D path (RDMA staging) applies.
pub fn swap_in_cost(model: ModelScale, cfg: &ClusterConfig, local: bool) -> SwapCost {
    let bw = if local {
        cfg.h2d_bw
    } else {
        cfg.h2d_bw.min(cfg.rdma_bw)
    };
    let penalty = if local { 1.0 } else { 1.15 }; // staging overhead
    SwapCost {
        control_s: RESUME_S,
        transfer_s: shard_transfer_s(model, cfg, bw) * penalty,
    }
}

/// Execute a swap-out against the real object store (used by the real
/// mini-cluster and the Fig. 6 integration test): publishes each state
/// shard under `agent/<id>/state`, returns the modeled cost.
pub fn swap_out(
    store: &MemStore,
    transfer: &TransferModel,
    agent: usize,
    model: ModelScale,
    device0: usize,
    payload: Option<Vec<u8>>,
) -> SwapCost {
    let node = device0 / transfer.cfg.devices_per_node;
    store.set(
        &format!("agent/{agent}/train_state"),
        Location::Host(node),
        model.train_state_bytes(),
        payload,
    );
    swap_out_cost(model, &transfer.cfg)
}

/// Execute a swap-in: resolves the checkpoint via Get, relocates it to
/// the destination device, returns the modeled cost.
pub fn swap_in(
    store: &MemStore,
    transfer: &TransferModel,
    agent: usize,
    model: ModelScale,
    dst_device: usize,
) -> Option<SwapCost> {
    let key = format!("agent/{agent}/train_state");
    let meta = store.meta(&key)?;
    let local = match meta.location {
        Location::Host(n) => n == dst_device / transfer.cfg.devices_per_node,
        Location::Device(_) => false,
    };
    store.take(&key, Location::Device(dst_device), transfer)?;
    Some(swap_in_cost(model, &transfer.cfg, local))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ClusterConfig {
        ClusterConfig::default()
    }

    #[test]
    fn fig11_offload_grows_with_model_size() {
        let sizes = [ModelScale::B3, ModelScale::B7, ModelScale::B14, ModelScale::B32];
        let costs: Vec<f64> = sizes
            .iter()
            .map(|&m| swap_out_cost(m, &cfg()).transfer_s)
            .collect();
        for w in costs.windows(2) {
            assert!(w[1] > w[0], "{costs:?}");
        }
        // Paper band: 0.5 s (3B) → 3.8 s (32B).
        assert!(costs[0] > 0.1 && costs[0] < 1.2, "3B offload {}", costs[0]);
        assert!(costs[3] > 1.8 && costs[3] < 6.0, "32B offload {}", costs[3]);
    }

    #[test]
    fn fig11_control_plane_constant() {
        let a = swap_out_cost(ModelScale::B3, &cfg()).control_s;
        let b = swap_out_cost(ModelScale::B32, &cfg()).control_s;
        assert_eq!(a, b);
    }

    #[test]
    fn fig11_total_swap_within_budget() {
        // "our state swap overhead is only 11 s for the largest model".
        let total = swap_out_cost(ModelScale::B32, &cfg()).total()
            + swap_in_cost(ModelScale::B32, &cfg(), true).total();
        assert!(total < 12.0, "total {total}");
        assert!(total > 3.0, "{total}"); // it is not free either
    }

    #[test]
    fn nonlocal_resume_costs_more() {
        let local = swap_in_cost(ModelScale::B14, &cfg(), true).total();
        let remote = swap_in_cost(ModelScale::B14, &cfg(), false).total();
        assert!(remote > local);
    }

    #[test]
    fn store_roundtrip_relocates_state() {
        let store = MemStore::new();
        let t = TransferModel::new(cfg());
        let out = swap_out(&store, &t, 3, ModelScale::B14, 32, Some(vec![7; 16]));
        assert!(out.total() > SUSPEND_S);
        let meta = store.meta("agent/3/train_state").unwrap();
        assert_eq!(meta.location, Location::Host(2)); // device 32 → node 2
        // Resume on the same node → H2D; meta moves to the device.
        let in_local = swap_in(&store, &t, 3, ModelScale::B14, 33).unwrap();
        let in_cost_remote = swap_in_cost(ModelScale::B14, &cfg(), false);
        assert!(in_local.total() < in_cost_remote.total() + RESUME_S);
        assert_eq!(
            store.meta("agent/3/train_state").unwrap().location,
            Location::Device(33)
        );
        assert!(swap_in(&store, &t, 99, ModelScale::B14, 0).is_none());
    }
}
