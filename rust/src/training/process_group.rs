//! Process groups (§6.1): gang-scheduled lifecycle management of all
//! training processes belonging to one agent.
//!
//! "Suspend-to-destroy": suspending a group *terminates* its processes
//! and returns every device to the cluster pool immediately (unlike
//! naive suspension that parks process contexts in HBM); resuming
//! re-creates the group from the last checkpoint, preferring the node it
//! previously occupied (locality-aware, §6.2) to minimize state-swap
//! cost.

use crate::cluster::{DevicePool, NodeId, Placement, PlacementStrategy};
use crate::config::ModelScale;

#[derive(Debug, Clone, PartialEq)]
pub enum GroupState {
    /// No processes, no devices; states (if any) checkpointed on host.
    Destroyed,
    /// Gang-scheduled and running on a placement.
    Active(Placement),
}

#[derive(Debug)]
pub struct ProcessGroup {
    pub agent: usize,
    pub model: ModelScale,
    pub state: GroupState,
    /// Node of the last activation (locality preference on resume).
    pub last_node: Option<NodeId>,
    /// Checkpoint bookkeeping: how many times states were saved/restored.
    pub swaps_out: u64,
    pub swaps_in: u64,
    /// Micro batches processed since last parameter update (gradient
    /// cache occupancy, §4.3).
    pub cached_micro_batches: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActivateError {
    AlreadyActive,
    InsufficientResources,
}

impl ProcessGroup {
    pub fn new(agent: usize, model: ModelScale) -> Self {
        ProcessGroup {
            agent,
            model,
            state: GroupState::Destroyed,
            last_node: None,
            swaps_out: 0,
            swaps_in: 0,
            cached_micro_batches: 0,
        }
    }

    pub fn is_active(&self) -> bool {
        matches!(self.state, GroupState::Active(_))
    }

    pub fn devices_needed(&self) -> usize {
        self.model.train_group_devices()
    }

    /// Gang-schedule the group: all devices or nothing (§6.1 cites
    /// Feitelson's gang scheduling). Returns whether the placement landed
    /// on the preferred (previous) node — the swap-in path differs.
    pub fn activate(
        &mut self,
        pool: &mut DevicePool,
        strategy: PlacementStrategy,
        dpn: usize,
    ) -> Result<(Placement, bool), ActivateError> {
        if self.is_active() {
            return Err(ActivateError::AlreadyActive);
        }
        let placement = pool
            .allocate(self.devices_needed(), strategy, self.last_node)
            .ok_or(ActivateError::InsufficientResources)?;
        let node = placement.devices[0] / dpn;
        let local = self.last_node == Some(node) || self.last_node.is_none();
        self.last_node = Some(node);
        self.state = GroupState::Active(placement.clone());
        self.swaps_in += u64::from(!local || self.swaps_out > 0);
        Ok((placement, local))
    }

    /// Suspend-to-destroy: checkpoint + terminate + release all devices.
    pub fn destroy(&mut self, pool: &mut DevicePool) -> Option<Placement> {
        match std::mem::replace(&mut self.state, GroupState::Destroyed) {
            GroupState::Active(p) => {
                pool.release(&p);
                self.swaps_out += 1;
                self.cached_micro_batches = 0;
                Some(p)
            }
            GroupState::Destroyed => None,
        }
    }

    pub fn placement(&self) -> Option<&Placement> {
        match &self.state {
            GroupState::Active(p) => Some(p),
            GroupState::Destroyed => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn pool() -> (DevicePool, usize) {
        let cfg = ClusterConfig {
            nodes: 4,
            devices_per_node: 16,
            ..ClusterConfig::default()
        };
        (DevicePool::whole_cluster(cfg), cfg.devices_per_node)
    }

    #[test]
    fn gang_all_or_nothing() {
        let (mut pool, dpn) = pool();
        let mut g = ProcessGroup::new(0, ModelScale::B14); // needs 8
        let (p, _) = g.activate(&mut pool, PlacementStrategy::StrictPack, dpn).unwrap();
        assert_eq!(p.devices.len(), 8);
        assert!(g.is_active());
        assert_eq!(pool.in_use(), 8);
        assert!(matches!(
            g.activate(&mut pool, PlacementStrategy::StrictPack, dpn),
            Err(ActivateError::AlreadyActive)
        ));
    }

    #[test]
    fn destroy_releases_everything() {
        let (mut pool, dpn) = pool();
        let mut g = ProcessGroup::new(0, ModelScale::B32); // needs 16
        g.activate(&mut pool, PlacementStrategy::StrictPack, dpn).unwrap();
        g.cached_micro_batches = 3;
        let released = g.destroy(&mut pool).unwrap();
        assert_eq!(released.devices.len(), 16);
        assert_eq!(pool.in_use(), 0);
        assert!(!g.is_active());
        assert_eq!(g.cached_micro_batches, 0);
        assert_eq!(g.swaps_out, 1);
        assert!(g.destroy(&mut pool).is_none()); // idempotent
    }

    #[test]
    fn resume_prefers_previous_node() {
        let (mut pool, dpn) = pool();
        let mut g = ProcessGroup::new(0, ModelScale::B14);
        let (p1, _) = g.activate(&mut pool, PlacementStrategy::StrictPack, dpn).unwrap();
        let node1 = p1.devices[0] / dpn;
        g.destroy(&mut pool);
        // Occupy part of the cluster so the preference matters.
        let _other = pool.allocate(8, PlacementStrategy::StrictPack, None);
        let (p2, local) = g.activate(&mut pool, PlacementStrategy::StrictPack, dpn).unwrap();
        assert_eq!(p2.devices[0] / dpn, node1);
        assert!(local);
    }

    #[test]
    fn resume_elsewhere_when_previous_node_full() {
        let (mut pool, dpn) = pool();
        let mut g = ProcessGroup::new(0, ModelScale::B14);
        let (p1, _) = g.activate(&mut pool, PlacementStrategy::StrictPack, dpn).unwrap();
        let node1 = p1.devices[0] / dpn;
        g.destroy(&mut pool);
        // Fill the previous node completely.
        let mut held = Vec::new();
        while pool.available_on(node1) > 0 {
            held.push(pool.allocate(1, PlacementStrategy::StrictPack, Some(node1)).unwrap());
        }
        let (p2, local) = g.activate(&mut pool, PlacementStrategy::StrictPack, dpn).unwrap();
        assert_ne!(p2.devices[0] / dpn, node1);
        assert!(!local); // cross-node resume → RH2D swap path
    }

    #[test]
    fn insufficient_resources_is_clean() {
        let (mut pool, dpn) = pool();
        let _hog = pool.allocate(60, PlacementStrategy::Pack, None).unwrap();
        let mut g = ProcessGroup::new(0, ModelScale::B14);
        assert!(matches!(
            g.activate(&mut pool, PlacementStrategy::StrictPack, dpn),
            Err(ActivateError::InsufficientResources)
        ));
        assert!(!g.is_active());
        assert_eq!(pool.in_use(), 60);
    }
}
