//! Training engine (§6): agent-centric resource allocation + state swap.
//!
//! * [`process_group`] — gang-scheduled per-agent process groups with
//!   suspend-to-destroy semantics;
//! * [`allocator`] — the shared-pool agent-centric allocator vs the
//!   static-partition baseline;
//! * [`swap`] — training-state swap-in/out cost model and Set/Get
//!   execution (Figs. 6 and 11).

pub mod allocator;
pub mod process_group;
pub mod swap;

pub use allocator::{AgentCentricAllocator, StaticAllocator};
pub use process_group::{ActivateError, GroupState, ProcessGroup};
pub use swap::{swap_in, swap_in_cost, swap_out, swap_out_cost, SwapCost, RESUME_S, SUSPEND_S};

use crate::config::ModelScale;

/// Gradient-computation time for one micro batch of `tokens` on a
/// process group (fwd+bwd, ZeRO-3). Used by the simulator.
pub fn grad_compute_s(model: ModelScale, tokens: f64) -> f64 {
    let devices = model.train_group_devices() as f64;
    tokens / (model.train_tps_per_device() * devices)
}

/// Unified parameter-update time (optimizer step + gradient aggregation
/// across cached micro batches) — brief relative to grad compute.
pub fn apply_update_s(model: ModelScale) -> f64 {
    // Optimizer math is memory-bound over the state bytes.
    let devices = model.train_group_devices() as f64;
    let bytes_per_device = model.train_state_bytes() / devices;
    bytes_per_device / 900e9 + 0.05 // HBM rw pass + launch overhead
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grad_time_scales_with_model_and_tokens() {
        let t14 = grad_compute_s(ModelScale::B14, 16_000.0);
        let t32 = grad_compute_s(ModelScale::B32, 16_000.0);
        // 32B has ~2.3× FLOPs/token over 2× devices → slower per token.
        assert!(t32 > t14);
        assert!(grad_compute_s(ModelScale::B14, 32_000.0) > t14 * 1.9);
        // Magnitude: a 16-sample micro batch (~25k tokens) on 14B/8 dev
        // should take O(10 s), consistent with DistRL's 155.9 s full
        // batch training on MA (Table 2 / Fig. 7).
        assert!(t14 > 1.0 && t14 < 60.0, "{t14}");
    }

    #[test]
    fn apply_is_cheap_relative_to_grad() {
        for m in [ModelScale::B3, ModelScale::B14, ModelScale::B32] {
            assert!(apply_update_s(m) < grad_compute_s(m, 16_000.0) / 3.0);
        }
    }
}
