//! Agent-centric resource allocation (§6.1) vs the static baseline.
//!
//! Agent-centric: the training pool is a shared free list; a process
//! group binds devices only while it has micro batches to process
//! (suspend-to-destroy in between). Static: every agent receives a fixed
//! partition at startup and holds it for the whole run — the
//! Obs. 3 configuration whose utilization collapses to ~18.8%.

use crate::ckpt::{as_ju64, ju64};
use crate::cluster::{DevicePool, Placement, PlacementStrategy};
use crate::config::{ClusterConfig, ModelScale};
use crate::training::process_group::{ActivateError, GroupState, ProcessGroup};
use crate::util::json::Json;

pub struct AgentCentricAllocator {
    pub pool: DevicePool,
    pub groups: Vec<ProcessGroup>,
    dpn: usize,
    /// Agents waiting for devices (FIFO fairness).
    wait_queue: Vec<usize>,
}

impl AgentCentricAllocator {
    pub fn new(pool: DevicePool, models: &[ModelScale], cfg: &ClusterConfig) -> Self {
        AgentCentricAllocator {
            pool,
            groups: models
                .iter()
                .enumerate()
                .map(|(i, &m)| ProcessGroup::new(i, m))
                .collect(),
            dpn: cfg.devices_per_node,
            wait_queue: Vec::new(),
        }
    }

    /// Try to bind resources for `agent`. On success returns
    /// (placement, resumed_locally) so the caller can charge the right
    /// swap-in path. Contention queues the agent FIFO.
    pub fn activate(&mut self, agent: usize) -> Option<(Placement, bool)> {
        if self.groups[agent].is_active() {
            return None;
        }
        // FIFO fairness: if others are waiting, only the head may bind.
        if let Some(&head) = self.wait_queue.first() {
            if head != agent {
                if !self.wait_queue.contains(&agent) {
                    self.wait_queue.push(agent);
                }
                return None;
            }
        }
        match self.groups[agent].activate(&mut self.pool, PlacementStrategy::StrictPack, self.dpn)
        {
            Ok((p, local)) => {
                self.wait_queue.retain(|&a| a != agent);
                Some((p, local))
            }
            Err(ActivateError::InsufficientResources) => {
                if !self.wait_queue.contains(&agent) {
                    self.wait_queue.push(agent);
                }
                None
            }
            Err(ActivateError::AlreadyActive) => None,
        }
    }

    /// Suspend-to-destroy `agent`'s group; returns the freed placement.
    pub fn release(&mut self, agent: usize) -> Option<Placement> {
        self.groups[agent].destroy(&mut self.pool)
    }

    /// Next queued agent that could now fit (to be activated by caller).
    pub fn next_waiter(&self) -> Option<usize> {
        self.wait_queue
            .first()
            .copied()
            .filter(|&a| self.pool.available() >= self.groups[a].devices_needed())
    }

    pub fn active_devices(&self) -> usize {
        self.pool.in_use()
    }

    // ---- checkpointing (DESIGN.md §12) ------------------------------------

    /// Checkpoint capture: pool free lists, every group's lifecycle
    /// state (placement, locality memory, swap counters, gradient-cache
    /// occupancy), and the FIFO wait queue. Group identity (`agent`,
    /// `model`) is config-derived and rebuilt at restore.
    pub fn snapshot(&self) -> Json {
        Json::obj(vec![
            ("pool", self.pool.snapshot()),
            (
                "wait_queue",
                Json::arr(self.wait_queue.iter().map(|&a| Json::num(a as f64))),
            ),
            (
                "groups",
                Json::arr(self.groups.iter().map(|g| {
                    let placement = match &g.state {
                        GroupState::Destroyed => Json::Null,
                        GroupState::Active(p) => {
                            Json::arr(p.devices.iter().map(|&d| Json::num(d as f64)))
                        }
                    };
                    Json::obj(vec![
                        ("placement", placement),
                        (
                            "last_node",
                            g.last_node
                                .map(|n| Json::num(n as f64))
                                .unwrap_or(Json::Null),
                        ),
                        ("swaps_out", ju64(g.swaps_out)),
                        ("swaps_in", ju64(g.swaps_in)),
                        (
                            "cached_micro_batches",
                            Json::num(g.cached_micro_batches as f64),
                        ),
                    ])
                })),
            ),
        ])
    }

    /// Restore an [`AgentCentricAllocator::snapshot`] into an allocator
    /// freshly built from the same config (same model list, same pool
    /// node range).
    pub fn restore_from(&mut self, j: &Json) -> Result<(), String> {
        self.pool
            .restore_from(j.get("pool").ok_or("allocator missing 'pool'")?)?;
        let wq = j
            .get("wait_queue")
            .and_then(Json::as_arr)
            .ok_or("allocator missing 'wait_queue'")?;
        self.wait_queue = wq
            .iter()
            .map(|a| a.as_usize().ok_or("bad wait_queue entry".to_string()))
            .collect::<Result<_, _>>()?;
        let groups = j
            .get("groups")
            .and_then(Json::as_arr)
            .ok_or("allocator missing 'groups'")?;
        if groups.len() != self.groups.len() {
            return Err(format!(
                "allocator has {} groups, checkpoint has {}",
                self.groups.len(),
                groups.len()
            ));
        }
        for (g, gj) in self.groups.iter_mut().zip(groups) {
            g.state = match gj.get("placement") {
                Some(Json::Null) | None => GroupState::Destroyed,
                Some(arr) => {
                    let devices = arr
                        .as_arr()
                        .ok_or("bad group placement")?
                        .iter()
                        .map(|d| d.as_usize().ok_or("bad device id".to_string()))
                        .collect::<Result<Vec<_>, _>>()?;
                    GroupState::Active(Placement { devices })
                }
            };
            g.last_node = match gj.get("last_node") {
                Some(Json::Null) | None => None,
                Some(n) => Some(n.as_usize().ok_or("bad last_node")?),
            };
            g.swaps_out = gj
                .get("swaps_out")
                .and_then(as_ju64)
                .ok_or("group missing 'swaps_out'")?;
            g.swaps_in = gj
                .get("swaps_in")
                .and_then(as_ju64)
                .ok_or("group missing 'swaps_in'")?;
            g.cached_micro_batches = gj
                .get("cached_micro_batches")
                .and_then(Json::as_usize)
                .ok_or("group missing 'cached_micro_batches'")?;
        }
        Ok(())
    }
}

/// Static allocation: fixed one-group-per-agent partition, held forever.
/// Returns None if the pool cannot host every agent simultaneously (the
/// scalability failure the paper describes — OOM on heterogeneous
/// ensembles).
pub struct StaticAllocator {
    pub placements: Vec<Placement>,
    pub total_devices: usize,
}

impl StaticAllocator {
    pub fn new(pool: &mut DevicePool, models: &[ModelScale]) -> Option<Self> {
        let total = pool.total_devices();
        let mut placements = Vec::with_capacity(models.len());
        for m in models {
            match pool.allocate(m.train_group_devices(), PlacementStrategy::Pack, None) {
                Some(p) => placements.push(p),
                None => {
                    for p in &placements {
                        pool.release(p);
                    }
                    return None;
                }
            }
        }
        Some(StaticAllocator {
            placements,
            total_devices: total,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(nodes: usize) -> (AgentCentricAllocator, ClusterConfig) {
        let cfg = ClusterConfig {
            nodes,
            devices_per_node: 16,
            ..ClusterConfig::default()
        };
        let pool = DevicePool::whole_cluster(cfg);
        let models = vec![ModelScale::B14; 4]; // 8 devices each
        (AgentCentricAllocator::new(pool, &models, &cfg), cfg)
    }

    #[test]
    fn on_demand_binding_and_release() {
        let (mut a, _) = setup(1); // 16 devices: two 14B groups fit
        assert!(a.activate(0).is_some());
        assert!(a.activate(1).is_some());
        assert_eq!(a.active_devices(), 16);
        assert!(a.activate(2).is_none()); // queued
        a.release(0);
        assert_eq!(a.active_devices(), 8);
        assert_eq!(a.next_waiter(), Some(2));
        assert!(a.activate(2).is_some());
    }

    #[test]
    fn fifo_fairness_under_contention() {
        let (mut a, _) = setup(1);
        a.activate(0);
        a.activate(1);
        assert!(a.activate(2).is_none());
        assert!(a.activate(3).is_none());
        a.release(0);
        // Agent 3 may not jump the queue.
        assert!(a.activate(3).is_none());
        assert!(a.activate(2).is_some());
        a.release(1);
        assert!(a.activate(3).is_some());
    }

    #[test]
    fn more_agents_than_capacity_time_multiplexes() {
        let (mut a, _) = setup(1);
        // 4 agents × 8 devices = 32 needed, 16 available: the whole point
        // of agent-centric allocation (massive ensembles, §6.1).
        let mut done = 0;
        let mut active: Vec<usize> = Vec::new();
        for round in 0..16 {
            for agent in 0..4 {
                if !a.groups[agent].is_active() && a.activate(agent).is_some() {
                    active.push(agent);
                }
            }
            if let Some(agent) = active.pop() {
                a.release(agent);
                done += 1;
            }
            let _ = round;
        }
        assert!(done >= 8, "only {done} train slots over 16 rounds");
    }

    #[test]
    fn static_allocator_oom_on_oversubscription() {
        let cfg = ClusterConfig {
            nodes: 1,
            devices_per_node: 16,
            ..ClusterConfig::default()
        };
        let mut pool = DevicePool::whole_cluster(cfg);
        // 3 × 14B groups need 24 > 16 devices → static allocation fails
        // (the Table 4 "existing frameworks OOM" behaviour).
        assert!(StaticAllocator::new(&mut pool, &vec![ModelScale::B14; 3]).is_none());
        assert_eq!(pool.available(), 16); // clean rollback
        // 2 groups fit and hold everything forever.
        let s = StaticAllocator::new(&mut pool, &vec![ModelScale::B14; 2]).unwrap();
        assert_eq!(s.placements.len(), 2);
        assert_eq!(pool.available(), 0);
    }
}
