//! Cluster substrate: nodes, devices, HBM accounting, and placement
//! groups (the §9 "Cross-Node Agent Deployment" lesson).
//!
//! The paper found that a single cluster-wide placement group with Ray's
//! "PACK" strategy scatters one agent's processes across nodes (logical
//! bundle order ≠ physical device ids), causing cross-node traffic and
//! instability; FlexMARL instantiates per-node groups with "STRICT_PACK"
//! and a deterministic bundle→device mapping. We reproduce both
//! strategies so the ablation bench can quantify the difference.

use crate::config::ClusterConfig;
use crate::util::json::Json;

pub type NodeId = usize;
pub type DeviceId = usize; // global id = node * devices_per_node + local

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementStrategy {
    /// Cluster-level group, bundles packed by logical order — may split
    /// one allocation across nodes (the failure mode).
    Pack,
    /// Per-node groups, one-to-one logical→physical mapping — an
    /// allocation never spans nodes unless larger than a node.
    StrictPack,
}

/// A granted placement: the device set backing one inference instance or
/// one training process group.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    pub devices: Vec<DeviceId>,
}

impl Placement {
    pub fn nodes(&self, cfg: &ClusterConfig) -> Vec<NodeId> {
        let mut nodes: Vec<NodeId> = self
            .devices
            .iter()
            .map(|d| d / cfg.devices_per_node)
            .collect();
        nodes.sort_unstable();
        nodes.dedup();
        nodes
    }

    pub fn crosses_nodes(&self, cfg: &ClusterConfig) -> bool {
        self.nodes(cfg).len() > 1
    }

    pub fn primary_node(&self, cfg: &ClusterConfig) -> NodeId {
        self.devices[0] / cfg.devices_per_node
    }
}

/// Device pool with free-list allocation per node.
#[derive(Debug, Clone)]
pub struct DevicePool {
    cfg: ClusterConfig,
    /// Device ids in this pool (a pool is a *subset* of the cluster —
    /// disaggregation gives rollout and training disjoint pools).
    free: Vec<Vec<DeviceId>>, // per node, sorted descending for O(1) pop
    total: usize,
    in_use: usize,
}

impl DevicePool {
    /// Pool over node range [node_lo, node_hi).
    pub fn new(cfg: ClusterConfig, node_lo: NodeId, node_hi: NodeId) -> Self {
        assert!(node_hi <= cfg.nodes && node_lo < node_hi);
        let mut free = vec![Vec::new(); cfg.nodes];
        let mut total = 0;
        for node in node_lo..node_hi {
            let base = node * cfg.devices_per_node;
            // Descending so pop() hands out low ids first.
            free[node] = (0..cfg.devices_per_node).rev().map(|i| base + i).collect();
            total += cfg.devices_per_node;
        }
        DevicePool {
            cfg,
            free,
            total,
            in_use: 0,
        }
    }

    pub fn whole_cluster(cfg: ClusterConfig) -> Self {
        Self::new(cfg, 0, cfg.nodes)
    }

    pub fn total_devices(&self) -> usize {
        self.total
    }

    pub fn available(&self) -> usize {
        self.total - self.in_use
    }

    pub fn in_use(&self) -> usize {
        self.in_use
    }

    /// Free devices on `node`.
    pub fn available_on(&self, node: NodeId) -> usize {
        self.free[node].len()
    }

    /// Allocate `n` devices.
    ///
    /// `StrictPack`: all `n` from a single node (preferring
    /// `preferred_node` — the locality-aware scheduling of §6.2); if `n`
    /// exceeds a node, whole nodes first, remainder strict-packed.
    /// `Pack`: fill nodes in logical order regardless of boundaries —
    /// faithfully reproducing the fragmentation failure mode.
    pub fn allocate(
        &mut self,
        n: usize,
        strategy: PlacementStrategy,
        preferred_node: Option<NodeId>,
    ) -> Option<Placement> {
        if n == 0 || self.available() < n {
            return None;
        }
        let devices = match strategy {
            PlacementStrategy::StrictPack => self.alloc_strict(n, preferred_node)?,
            PlacementStrategy::Pack => self.alloc_pack(n)?,
        };
        self.in_use += devices.len();
        Some(Placement { devices })
    }

    fn alloc_strict(&mut self, n: usize, preferred: Option<NodeId>) -> Option<Vec<DeviceId>> {
        let per_node = self.cfg.devices_per_node;
        let mut out = Vec::with_capacity(n);
        let mut remaining = n;

        // Multi-node allocations take whole nodes first.
        while remaining > per_node {
            let node = self.fullest_node(per_node, preferred)?;
            for _ in 0..per_node {
                out.push(self.free[node].pop().unwrap());
            }
            remaining -= per_node;
        }
        // Remainder from one node, preferring locality then best-fit
        // (smallest sufficient free set → less fragmentation).
        let node = self.fit_node(remaining, preferred).or_else(|| {
            // Roll back if we can't finish.
            for d in out.drain(..) {
                self.free[d / per_node].push(d);
            }
            None
        })?;
        for _ in 0..remaining {
            out.push(self.free[node].pop().unwrap());
        }
        Some(out)
    }

    fn fullest_node(&self, need: usize, preferred: Option<NodeId>) -> Option<NodeId> {
        if let Some(p) = preferred {
            if self.free[p].len() >= need {
                return Some(p);
            }
        }
        (0..self.cfg.nodes)
            .filter(|&i| self.free[i].len() >= need)
            .max_by_key(|&i| self.free[i].len())
    }

    fn fit_node(&self, need: usize, preferred: Option<NodeId>) -> Option<NodeId> {
        if need == 0 {
            return Some(preferred.unwrap_or(0));
        }
        if let Some(p) = preferred {
            if self.free[p].len() >= need {
                return Some(p);
            }
        }
        (0..self.cfg.nodes)
            .filter(|&i| self.free[i].len() >= need)
            .min_by_key(|&i| self.free[i].len())
    }

    fn alloc_pack(&mut self, n: usize) -> Option<Vec<DeviceId>> {
        // Logical-order packing: walk nodes, take whatever is free. This
        // is what splits an agent's bundle across node boundaries.
        let mut out = Vec::with_capacity(n);
        for node in 0..self.cfg.nodes {
            while out.len() < n {
                match self.free[node].pop() {
                    Some(d) => out.push(d),
                    None => break,
                }
            }
            if out.len() == n {
                return Some(out);
            }
        }
        // Shouldn't happen (available checked), but roll back defensively.
        for d in out {
            self.free[d / self.cfg.devices_per_node].push(d);
        }
        None
    }

    pub fn release(&mut self, placement: &Placement) {
        for &d in &placement.devices {
            let node = d / self.cfg.devices_per_node;
            debug_assert!(!self.free[node].contains(&d), "double free of device {d}");
            self.free[node].push(d);
        }
        self.in_use -= placement.devices.len();
    }

    // ---- checkpointing (DESIGN.md §12) ------------------------------------

    /// Checkpoint capture: per-node free lists in exact stack order
    /// (allocation pops from the end, so order determines which device
    /// ids future allocations receive) plus the in-use count. `cfg` and
    /// `total` are rebuilt from config at restore.
    pub fn snapshot(&self) -> Json {
        Json::obj(vec![
            (
                "free",
                Json::arr(self.free.iter().map(|node| {
                    Json::arr(node.iter().map(|&d| Json::num(d as f64)))
                })),
            ),
            ("in_use", Json::num(self.in_use as f64)),
        ])
    }

    /// Restore a [`DevicePool::snapshot`] into a pool freshly built
    /// from the same config. Shape mismatches (different node count or
    /// device totals) mean the checkpoint came from a different
    /// cluster layout and are reported as errors.
    pub fn restore_from(&mut self, j: &Json) -> Result<(), String> {
        let free_j = j
            .get("free")
            .and_then(Json::as_arr)
            .ok_or("device pool missing 'free'")?;
        if free_j.len() != self.free.len() {
            return Err(format!(
                "device pool has {} nodes, checkpoint has {}",
                self.free.len(),
                free_j.len()
            ));
        }
        let mut free = Vec::with_capacity(free_j.len());
        for node in free_j {
            let ids = node.as_arr().ok_or("device pool free list not an array")?;
            let mut v = Vec::with_capacity(ids.len());
            for id in ids {
                v.push(id.as_usize().ok_or("bad device id in checkpoint")?);
            }
            free.push(v);
        }
        let in_use = j
            .get("in_use")
            .and_then(Json::as_usize)
            .ok_or("device pool missing 'in_use'")?;
        let n_free: usize = free.iter().map(Vec::len).sum();
        if n_free + in_use != self.total {
            return Err(format!(
                "device pool count mismatch: {n_free} free + {in_use} in use != {} total",
                self.total
            ));
        }
        self.free = free;
        self.in_use = in_use;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    fn small_cfg() -> ClusterConfig {
        ClusterConfig {
            nodes: 4,
            devices_per_node: 8,
            ..ClusterConfig::default()
        }
    }

    #[test]
    fn strict_pack_never_splits_small_allocs() {
        let mut pool = DevicePool::whole_cluster(small_cfg());
        // 4 nodes × 8 devices: only one 5-device alloc fits per node.
        for _ in 0..4 {
            let p = pool
                .allocate(5, PlacementStrategy::StrictPack, None)
                .unwrap();
            assert!(!p.crosses_nodes(&small_cfg()), "{:?}", p.devices);
        }
        // 12 devices remain (3 per node) but STRICT_PACK refuses to split
        // a 5-device bundle across nodes — it fails rather than fragment.
        assert!(pool.allocate(5, PlacementStrategy::StrictPack, None).is_none());
        assert_eq!(pool.available(), 12);
    }

    #[test]
    fn pack_splits_across_nodes() {
        let cfg = small_cfg();
        let mut pool = DevicePool::whole_cluster(cfg);
        // Fragment node 0: take 5, leaving 3 free.
        let _hold = pool.allocate(5, PlacementStrategy::Pack, None).unwrap();
        // PACK takes node0's 3 remaining + 2 from node1 → split bundle.
        let p = pool.allocate(5, PlacementStrategy::Pack, None).unwrap();
        assert!(p.crosses_nodes(&cfg), "{:?}", p.devices);
    }

    #[test]
    fn strict_pack_avoids_split_where_pack_splits() {
        let cfg = small_cfg();
        let mut pool = DevicePool::whole_cluster(cfg);
        let _hold = pool.allocate(5, PlacementStrategy::StrictPack, None).unwrap();
        let p = pool.allocate(5, PlacementStrategy::StrictPack, None).unwrap();
        assert!(!p.crosses_nodes(&cfg));
    }

    #[test]
    fn locality_preference_honored() {
        let cfg = small_cfg();
        let mut pool = DevicePool::whole_cluster(cfg);
        let p = pool
            .allocate(4, PlacementStrategy::StrictPack, Some(2))
            .unwrap();
        assert_eq!(p.primary_node(&cfg), 2);
    }

    #[test]
    fn multinode_alloc_takes_whole_nodes() {
        let cfg = small_cfg();
        let mut pool = DevicePool::whole_cluster(cfg);
        let p = pool
            .allocate(20, PlacementStrategy::StrictPack, None)
            .unwrap();
        assert_eq!(p.devices.len(), 20);
        assert_eq!(p.nodes(&cfg).len(), 3); // 8 + 8 + 4
        assert_eq!(pool.available(), 12);
    }

    #[test]
    fn exhaustion_returns_none_and_rolls_back() {
        let cfg = small_cfg();
        let mut pool = DevicePool::whole_cluster(cfg);
        let held: Vec<_> = (0..4)
            .map(|_| pool.allocate(7, PlacementStrategy::StrictPack, None).unwrap())
            .collect();
        assert_eq!(pool.available(), 4);
        assert!(pool.allocate(5, PlacementStrategy::StrictPack, None).is_none());
        assert_eq!(pool.available(), 4); // unchanged after failed alloc
        for p in &held {
            pool.release(p);
        }
        assert_eq!(pool.available(), 32);
    }

    #[test]
    fn pool_subsets_are_disjoint() {
        let cfg = small_cfg();
        let mut rollout = DevicePool::new(cfg, 0, 3);
        let mut training = DevicePool::new(cfg, 3, 4);
        assert_eq!(rollout.total_devices(), 24);
        assert_eq!(training.total_devices(), 8);
        let a = rollout.allocate(24, PlacementStrategy::Pack, None).unwrap();
        let b = training.allocate(8, PlacementStrategy::Pack, None).unwrap();
        assert!(a.devices.iter().all(|d| !b.devices.contains(d)));
    }

    #[test]
    fn prop_alloc_release_conserves_devices() {
        forall("alloc/release conservation", 100, |rng| {
            let cfg = small_cfg();
            let mut pool = DevicePool::whole_cluster(cfg);
            let mut live: Vec<Placement> = Vec::new();
            for _ in 0..30 {
                if rng.f64() < 0.6 {
                    let n = rng.below(10) as usize + 1;
                    let strat = if rng.f64() < 0.5 {
                        PlacementStrategy::Pack
                    } else {
                        PlacementStrategy::StrictPack
                    };
                    if let Some(p) = pool.allocate(n, strat, None) {
                        assert_eq!(p.devices.len(), n);
                        live.push(p);
                    }
                } else if !live.is_empty() {
                    let i = rng.below(live.len() as u64) as usize;
                    pool.release(&live.swap_remove(i));
                }
                // Invariants: no device appears twice across live placements.
                let mut all: Vec<DeviceId> =
                    live.iter().flat_map(|p| p.devices.iter().copied()).collect();
                let n_live = all.len();
                all.sort_unstable();
                all.dedup();
                assert_eq!(all.len(), n_live, "duplicate device granted");
                assert_eq!(pool.available() + n_live, 32);
            }
        });
    }
}
