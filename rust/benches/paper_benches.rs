//! `cargo bench` target regenerating every table AND figure of the
//! paper's evaluation (§8), plus the ablation benches DESIGN.md §7 calls
//! out (micro-batch size, Δ threshold, suspend-to-destroy vs retain,
//! contiguous vs per-parameter weight sync, PACK vs STRICT_PACK).
//!
//! criterion is not vendored in this image; this is a `harness = false`
//! bench built on `flexmarl::util::bench`. Each section prints the
//! paper's reported values next to the regenerated ones. Multi-run
//! sections (Table 2, Fig. 10, the scenario matrix) fan out through the
//! deterministic parallel executor ([`flexmarl::exec`], DESIGN.md §4) —
//! rows are bit-identical to a serial run, just faster to regenerate.

use flexmarl::baselines::{scenario_sweep, sweep, try_evaluate, Framework};
use flexmarl::cluster::{DevicePool, PlacementStrategy};
use flexmarl::config::{ClusterConfig, ExperimentConfig, ModelScale, WorkloadConfig};
use flexmarl::memstore::{Location, TransferModel};
use flexmarl::metrics::StepReport;
use flexmarl::orchestrator::{try_simulate, SimOptions, SimOutcome};
use flexmarl::training::{swap_in_cost, swap_out_cost};
use flexmarl::util::bench::time_once;

/// The non-panicking entry points, unwrapped (`simulate`/`evaluate`
/// are deprecated; bench configs are all statically valid).
fn simulate(cfg: &ExperimentConfig, opts: &SimOptions) -> SimOutcome {
    try_simulate(cfg, opts).unwrap()
}

fn evaluate(cfg: &ExperimentConfig, opts: &SimOptions) -> StepReport {
    try_evaluate(cfg, opts).unwrap()
}

fn opts() -> SimOptions {
    SimOptions {
        track_agents: vec![0, 1, 2],
        ..SimOptions::default()
    }
}

fn cfg(wl: WorkloadConfig, fw: Framework, steps: usize) -> ExperimentConfig {
    let mut c = ExperimentConfig::new(wl, fw);
    c.steps = steps;
    c
}

fn wl(name: &str) -> WorkloadConfig {
    if name == "MA" {
        WorkloadConfig::ma()
    } else {
        WorkloadConfig::ca()
    }
}

fn main() {
    println!("════════ FlexMARL paper benches (virtual-time cluster simulator) ════════");
    println!(
        "event queue backend: {:?} (bit-identical to the heap fallback; see tests)",
        opts().event_queue
    );
    bench_table2();
    bench_fig7();
    bench_fig1();
    bench_fig89();
    bench_fig10();
    bench_fig11();
    bench_table3();
    bench_table4();
    bench_scenarios();
    bench_ablation_micro_batch();
    bench_ablation_delta();
    bench_ablation_swap_policy();
    bench_weight_sync();
    bench_placement();
}

fn bench_table2() {
    println!("\n── Table 2: overall performance (paper → ours) ──");
    let paper = [
        ("MA", [914.4, 293.8, 174.1, 126.1]),
        ("CA", [438.6, 130.0, 112.8, 78.8]),
    ];
    for (w, p) in paper {
        // All four frameworks through the parallel executor.
        let (rows, dt) = time_once(|| sweep(&cfg(wl(w), Framework::flexmarl(), 3), &opts()));
        let base = rows[0].e2e_s;
        println!("  {w} (regenerated in {:.2?}):", dt);
        for (r, pe) in rows.iter().zip(p) {
            println!(
                "    {:<10} paper {:>6.1}s ({:>3.1}x)   ours {:>6.1}s ({:>3.1}x)  {:>7.1}tps",
                r.framework,
                pe,
                p[0] / pe,
                r.e2e_s,
                base / r.e2e_s,
                r.throughput_tps()
            );
        }
    }
}

fn bench_fig7() {
    println!("\n── Fig 7: E2E breakdown ── (paper anchor: DistRL MA train 155.9s, FlexMARL 10.2s)");
    for w in ["MA", "CA"] {
        for fw in Framework::all_baselines() {
            let r = evaluate(&cfg(wl(w), fw, 3), &opts());
            println!(
                "    {w} {:<10} rollout {:>6.1}s | train {:>6.1}s | other {:>5.1}s",
                r.framework, r.rollout_s, r.train_s, r.other_s
            );
        }
    }
}

fn bench_fig1() {
    println!("\n── Fig 1(a): interaction-latency CDF (paper: long tail to ≈170s) ──");
    let out = simulate(&cfg(wl("MA"), Framework::dist_rl(), 1), &opts());
    let mut lats = out.reports[0].trajectory_latencies.clone();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for q in [0.5, 0.75, 0.9, 0.99, 1.0] {
        let idx = ((lats.len() - 1) as f64 * q) as usize;
        println!("    p{:<3} {:>7.1}s", (q * 100.0) as u32, lats[idx]);
    }
    println!("\n── Fig 1(b): queued requests over time (3 agents, DistRL) ──");
    for (a, s) in &out.series.queued {
        let peak = s.iter().map(|&(_, q)| q).max().unwrap_or(0);
        let t_peak = s.iter().max_by_key(|&&(_, q)| q).map(|&(t, _)| t).unwrap_or(0.0);
        println!("    agent {a}: peak queue {peak} @ {t_peak:.0}s");
    }
}

fn bench_fig89() {
    println!("\n── Figs 8/9: processed rollout load (paper: FlexMARL drains agent B ~2.7x faster than DistRL) ──");
    for w in ["MA", "CA"] {
        for fw in [Framework::mas_rl(), Framework::dist_rl(), Framework::marti(), Framework::flexmarl()] {
            let out = simulate(&cfg(wl(w), fw, 1), &opts());
            print!("    {w} {:<10}", fw.name);
            for (a, series) in &out.series.processed {
                let total = series.last().map(|&(_, c)| c).unwrap_or(0);
                let t_done = series
                    .iter()
                    .find(|&&(_, c)| c == total && total > 0)
                    .map(|&(t, _)| t)
                    .unwrap_or(0.0);
                print!("  a{a}:{total}req/{t_done:.0}s");
            }
            println!();
        }
    }
}

fn bench_fig10() {
    println!("\n── Fig 10: utilization (paper CA: 3.6 / 10.2 / 12.3 / 19.8 %) ──");
    for w in ["MA", "CA"] {
        print!("    {w}: ");
        for r in sweep(&cfg(wl(w), Framework::flexmarl(), 3), &opts()) {
            print!("{} {:.1}%  ", r.framework, r.utilization() * 100.0);
        }
        println!();
    }
}

fn bench_fig11() {
    println!("\n── Fig 11: swap overhead (paper: offload 0.5s@3B → 3.8s@32B, total ≤11s) ──");
    let c = ClusterConfig::default();
    for m in [ModelScale::B3, ModelScale::B7, ModelScale::B14, ModelScale::B32] {
        let o = swap_out_cost(m, &c);
        let i = swap_in_cost(m, &c, true);
        println!(
            "    {:>2}B: suspend {:.2}s + offload {:.2}s | resume {:.2}s + onload {:.2}s | total {:.1}s",
            m.params_b as u32, o.control_s, o.transfer_s, i.control_s, i.transfer_s,
            o.total() + i.total()
        );
    }
}

fn bench_table3() {
    println!("\n── Table 3: ablations (paper MA: w/o LB 152.2s, w/o async 256.2s, full 126.1s) ──");
    for w in ["MA", "CA"] {
        let mas = evaluate(&cfg(wl(w), Framework::mas_rl(), 3), &opts());
        for fw in [
            Framework::flexmarl_no_balancing(),
            Framework::flexmarl_no_async(),
            Framework::flexmarl(),
        ] {
            let r = evaluate(&cfg(wl(w), fw, 3), &opts());
            println!(
                "    {w} {:<24} {:>7.1}s  speedup {:>4.1}x  {:>7.1}tps",
                fw.name,
                r.e2e_s,
                mas.e2e_s / r.e2e_s,
                r.throughput_tps()
            );
        }
    }
}

fn bench_table4() {
    println!("\n── Table 4: heterogeneous scalability (paper: 160.3 / 132.5 / 41.9 s) ──");
    for spec in [
        vec![(5usize, ModelScale::B32)],
        vec![(3, ModelScale::B32), (7, ModelScale::B14)],
        vec![(15, ModelScale::B14)],
    ] {
        let w = WorkloadConfig::scale_config(&spec);
        let name = w.name.clone();
        let r = evaluate(&cfg(w, Framework::flexmarl(), 2), &opts());
        println!(
            "    {:<14} rollout {:>6.1}s  train {:>5.1}s  e2e {:>6.1}s  {:>7.1}tps",
            name, r.rollout_s, r.train_s, r.e2e_s, r.throughput_tps()
        );
    }
}

fn bench_scenarios() {
    println!("\n── Scenario matrix: traffic shapes × DistRL vs FlexMARL ──");
    println!("    (each preset stresses a different paper observation; `flexmarl scenarios`)");
    for fw in [Framework::dist_rl(), Framework::flexmarl()] {
        // 4 steps so diurnal presets reach their peak multiplier
        // (bursty's 3x arrives on step 3) — at 1 step the bursty row
        // would be byte-identical to baseline.
        let base = cfg(wl("MA"), fw, 4);
        for r in scenario_sweep(&base, &opts()) {
            println!(
                "    {:<13} {:<10} e2e {:>7.1}s  rollout {:>7.1}s  util {:>4.1}%  scale_ops {}",
                r.scenario,
                r.framework,
                r.e2e_s,
                r.rollout_s,
                r.utilization() * 100.0,
                r.scale_ops
            );
        }
    }
}

fn bench_ablation_micro_batch() {
    println!("\n── Ablation: micro-batch size (pipeline overlap factor) ──");
    for micro in [8, 16, 32, 64] {
        let mut c = cfg(wl("MA"), Framework::flexmarl(), 2);
        c.pipeline.micro_batch = micro;
        let r = evaluate(&c, &opts());
        println!(
            "    micro {:>2}: e2e {:>6.1}s  train-tail {:>5.1}s",
            micro, r.e2e_s, r.train_s
        );
    }
}

fn bench_ablation_delta() {
    println!("\n── Ablation: Δ threshold (responsiveness vs oscillation) ──");
    for delta in [2, 5, 10, 20] {
        let mut c = cfg(wl("MA"), Framework::flexmarl(), 2);
        c.pipeline.delta_threshold = delta;
        let r = evaluate(&c, &opts());
        println!(
            "    Δ={:<2}: e2e {:>6.1}s  rollout {:>6.1}s  scale_ops {}",
            delta, r.e2e_s, r.rollout_s, r.scale_ops
        );
    }
}

fn bench_ablation_swap_policy() {
    println!("\n── Ablation: suspend-to-destroy vs retain-in-HBM ──");
    // Retain-in-HBM = static allocation (devices never released): compare
    // agent-centric vs static variants on an oversubscribed ensemble.
    let spec = vec![(15usize, ModelScale::B14)];
    let w = WorkloadConfig::scale_config(&spec);
    let flex = evaluate(&cfg(w.clone(), Framework::flexmarl(), 2), &opts());
    let mut c_static = cfg(w, Framework::flexmarl(), 2);
    c_static.framework.agent_centric = false;
    c_static.framework.name = "FlexMARL (retain/static)";
    let stat = evaluate(&c_static, &opts());
    println!(
        "    suspend-to-destroy: e2e {:>6.1}s  util {:>4.1}%  (swap cost {:.1}s hidden)",
        flex.e2e_s,
        flex.utilization() * 100.0,
        flex.swap_s
    );
    println!(
        "    retain-in-HBM:      e2e {:>6.1}s  util {:>4.1}%  (needs Σ groups resident → OOM risk at scale)",
        stat.e2e_s,
        stat.utilization() * 100.0
    );
}

fn bench_weight_sync() {
    println!("\n── §9 lesson: parameter sync, contiguous vs per-parameter (paper: 200x) ──");
    let t = TransferModel::new(ClusterConfig::default());
    for m in [ModelScale::B14, ModelScale::B32] {
        let contiguous = t.plan(Location::Device(0), Location::Device(1), m.weight_bytes());
        let per_tensor = t.plan_per_param(
            Location::Device(0),
            Location::Device(1),
            m.weight_bytes(),
            (m.params() / 2000.0) as u64, // ~2k params/tensor
        );
        println!(
            "    {:>2}B: contiguous {:>7.3}s   per-tensor {:>8.1}s   speedup {:>5.0}x  (control-plane {:.1}% of naive)",
            m.params_b as u32,
            contiguous.seconds,
            per_tensor.seconds,
            per_tensor.seconds / contiguous.seconds,
            100.0 * (per_tensor.seconds - m.weight_bytes() / t.cfg.d2d_bw) / per_tensor.seconds,
        );
    }
}

fn bench_placement() {
    println!("\n── §9 lesson: PACK vs STRICT_PACK placement (cross-node bundles) ──");
    let ccfg = ClusterConfig {
        nodes: 8,
        devices_per_node: 16,
        ..ClusterConfig::default()
    };
    for strat in [PlacementStrategy::Pack, PlacementStrategy::StrictPack] {
        let mut pool = DevicePool::whole_cluster(ccfg);
        let mut split = 0;
        let mut total = 0;
        let mut failed = 0;
        // Mixed agent ensemble repeatedly allocating/releasing groups.
        let sizes = [8usize, 16, 8, 4, 8, 16, 4, 8];
        let mut live: Vec<_> = Vec::new();
        for round in 0..64 {
            let n = sizes[round % sizes.len()];
            match pool.allocate(n, strat, None) {
                Some(p) => {
                    total += 1;
                    if p.crosses_nodes(&ccfg) && n <= ccfg.devices_per_node {
                        split += 1;
                    }
                    live.push(p);
                }
                None => failed += 1,
            }
            if live.len() > 6 {
                let p = live.remove(round % live.len());
                pool.release(&p);
            }
        }
        println!(
            "    {:?}: {}/{} bundles split across nodes ({} alloc failures)",
            strat, split, total, failed
        );
    }
    println!("    (split bundles → cross-node traffic + instability; STRICT_PACK eliminates them)");
}
