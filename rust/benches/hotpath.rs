//! Hot-path micro-benches (deliverable (e) §Perf/L3): the coordinator
//! components on the request path, plus — when `artifacts/` exists — the
//! PJRT executable latencies that bound the real end-to-end run.
//!
//! `harness = false` bench on `flexmarl::util::bench` (criterion is not
//! vendored). Every result is also written to `BENCH_hotpath.json`
//! (name → ns/iter, mean over the timed iterations) next to the stdout
//! report so the perf trajectory stays trackable across PRs.
//!
//! Flags:
//!  * `--smoke` — CI mode: minimal iteration counts, no timing
//!    assertions; verifies the benches still run end-to-end.

use flexmarl::baselines::Framework;
use flexmarl::config::{ExperimentConfig, WorkloadConfig};
use flexmarl::dist::{socket::SocketTransport, DistPlan, DistSource};
use flexmarl::exec::{grid_report, run_specs_or_panic, RunGrid};
use flexmarl::experiment::Experiment;
use flexmarl::metrics::StepReport;
use flexmarl::orchestrator::{try_simulate, NullSink, SimOptions};
use flexmarl::policy::PolicyBundle;
use flexmarl::rollout::{heap::IndexedMinHeap, RolloutManager};
use flexmarl::serve::{ServeConfig, ServePlane};
use flexmarl::sim::{EventQueue, QueueKind};
use flexmarl::store::{
    grpo_schema, Blob, ExperienceStore, Field, PutRow, SampleId, Value,
};
use flexmarl::util::bench::{bench, black_box, time_once, BenchResult};
use flexmarl::util::json::Json;
use flexmarl::util::pool;
use flexmarl::util::rng::Pcg64;
use std::collections::BTreeMap;
use std::time::Duration;

/// Collects results for the stdout report and `BENCH_hotpath.json`.
struct Recorder {
    entries: Vec<(String, f64)>,
}

impl Recorder {
    fn new() -> Recorder {
        Recorder { entries: Vec::new() }
    }

    fn add(&mut self, r: BenchResult) {
        println!("{}", r.report());
        self.entries.push((r.name.clone(), r.mean.as_nanos() as f64));
    }

    fn write_json(&self, path: &str) {
        let map: BTreeMap<String, Json> = self
            .entries
            .iter()
            .map(|(n, ns)| (n.clone(), Json::num(*ns)))
            .collect();
        let text = Json::Obj(map).to_pretty();
        match std::fs::write(path, text) {
            Ok(()) => println!("\nwrote {path} ({} benches)", self.entries.len()),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    // Smoke mode still runs every bench body (so CI exercises the code
    // paths) but with a minimal measurement budget.
    let t = if smoke {
        Duration::from_millis(1)
    } else {
        Duration::from_millis(300)
    };
    println!(
        "════════ hot-path micro-benches{} ════════",
        if smoke { " (smoke)" } else { "" }
    );
    let mut rec = Recorder::new();
    bench_event_queue(&mut rec, t);
    bench_heap(&mut rec, t);
    bench_manager(&mut rec, t);
    bench_store(&mut rec, t);
    bench_json(&mut rec, t);
    bench_policy_dispatch(&mut rec, t);
    bench_sim_engine(&mut rec, t);
    bench_session(&mut rec, t);
    bench_sweep(smoke);
    bench_serve(smoke);
    bench_dist(smoke);
    if !smoke {
        bench_pjrt(&mut rec);
    }
    rec.write_json("BENCH_hotpath.json");
}

/// Sweep group: the fixed framework × scenario grid through the
/// deterministic parallel executor at jobs=1 vs jobs=N. Wall times go
/// to `BENCH_sweep.json` so the perf trajectory has sweep-throughput
/// numbers; the jobs=N output is asserted byte-identical to jobs=1
/// while we're here (the executor's whole contract).
fn bench_sweep(smoke: bool) {
    let mut base = ExperimentConfig::new(WorkloadConfig::ma(), Framework::flexmarl());
    base.steps = if smoke { 1 } else { 2 };
    base.workload.queries_per_step = 2;
    base.workload.group_size = if smoke { 4 } else { 8 };
    let grid = RunGrid::full();
    let specs = grid.specs(&base);
    let opts = SimOptions::default();
    let jobs_n = pool::default_jobs().max(2);

    let (r1, t1) = time_once(|| run_specs_or_panic(&base, &opts, &specs, 1));
    let (rn, tn) = time_once(|| run_specs_or_panic(&base, &opts, &specs, jobs_n));
    let render = |reports: &[StepReport]| grid_report(&base, &specs, reports).to_pretty();
    assert_eq!(render(&r1), render(&rn), "sweep output depends on thread count");

    let speedup = t1.as_secs_f64() / tn.as_secs_f64().max(1e-9);
    println!(
        "\nsweep grid ({} runs, {} frameworks × {} scenarios): \
         jobs=1 {:.2?}   jobs={jobs_n} {:.2?}   speedup {speedup:.2}x",
        specs.len(),
        grid.frameworks.len(),
        grid.scenarios.len(),
        t1,
        tn,
    );
    let map: BTreeMap<String, Json> = [
        ("grid_runs".to_string(), Json::num(specs.len() as f64)),
        ("jobs_n".to_string(), Json::num(jobs_n as f64)),
        ("jobs1_ns".to_string(), Json::num(t1.as_nanos() as f64)),
        ("jobsN_ns".to_string(), Json::num(tn.as_nanos() as f64)),
        ("speedup".to_string(), Json::num(speedup)),
    ]
    .into_iter()
    .collect();
    match std::fs::write("BENCH_sweep.json", Json::Obj(map).to_pretty()) {
        Ok(()) => println!("wrote BENCH_sweep.json"),
        Err(e) => eprintln!("could not write BENCH_sweep.json: {e}"),
    }
}

/// Serve group (DESIGN.md §13): the mixed tenant mix through the
/// serving plane at workers=1 vs workers=N. Wall times and real session
/// throughput go to `BENCH_serve.json`; the load report and every
/// per-session stream are asserted byte-identical across the two runs
/// while we're here (the plane's whole determinism contract).
fn bench_serve(smoke: bool) {
    let mut cfg = ServeConfig::mix("mixed", 2048).expect("mixed mix must exist");
    cfg.ticks = if smoke { 30 } else { 120 };
    let jobs_n = pool::default_jobs().max(2);

    let (r1, t1) = time_once(|| {
        ServePlane::new(cfg.clone(), 1).unwrap().run().unwrap()
    });
    let (rn, tn) = time_once(|| {
        ServePlane::new(cfg.clone(), jobs_n).unwrap().run().unwrap()
    });
    assert_eq!(
        r1.report.to_json().to_pretty(),
        rn.report.to_json().to_pretty(),
        "serve load report depends on worker count"
    );
    assert_eq!(r1.sessions.len(), rn.sessions.len());
    for (a, b) in r1.sessions.iter().zip(&rn.sessions) {
        assert_eq!(a.jsonl, b.jsonl, "session {} bytes depend on worker count", a.seq);
    }

    let sessions = r1.report.completed;
    let speedup = t1.as_secs_f64() / tn.as_secs_f64().max(1e-9);
    let sessions_per_s = sessions as f64 / tn.as_secs_f64().max(1e-9);
    println!(
        "\nserve mixed mix ({} ticks, {sessions} sessions): \
         workers=1 {:.2?}   workers={jobs_n} {:.2?}   speedup {speedup:.2}x \
         ({sessions_per_s:.0} sessions/s)",
        cfg.ticks, t1, tn,
    );
    let map: BTreeMap<String, Json> = [
        ("sessions".to_string(), Json::num(sessions as f64)),
        ("jobs_n".to_string(), Json::num(jobs_n as f64)),
        ("jobs1_ns".to_string(), Json::num(t1.as_nanos() as f64)),
        ("jobsN_ns".to_string(), Json::num(tn.as_nanos() as f64)),
        (
            "ns_per_session".to_string(),
            Json::num(tn.as_nanos() as f64 / (sessions as f64).max(1.0)),
        ),
        ("sessions_per_s".to_string(), Json::num(sessions_per_s)),
        ("speedup".to_string(), Json::num(speedup)),
    ]
    .into_iter()
    .collect();
    match std::fs::write("BENCH_serve.json", Json::Obj(map).to_pretty()) {
        Ok(()) => println!("wrote BENCH_serve.json"),
        Err(e) => eprintln!("could not write BENCH_serve.json: {e}"),
    }
}

/// Dist group (DESIGN.md §14): per-step workload generation through
/// the distributed plane at both transports vs the single-process
/// generator. ns/step per transport goes to `BENCH_dist.json`; the
/// three drains are asserted byte-identical while we're here (the
/// plane's whole determinism contract). The socket leg spawns real
/// `dist-worker` child processes of the CLI binary, so its number
/// includes process start-up and TCP framing.
fn bench_dist(smoke: bool) {
    use flexmarl::workload::{scenario, ScenarioSource, StepWorkload, WorkloadSource};

    let mut wl = WorkloadConfig::ma();
    wl.queries_per_step = if smoke { 2 } else { 8 };
    wl.group_size = if smoke { 4 } else { 8 };
    let steps = if smoke { 2 } else { 6 };
    let seed = 2048;
    let workers = pool::default_jobs().clamp(2, 4);
    let resolve = || scenario::resolve(&wl).expect("baseline preset");

    fn drain(src: &mut dyn WorkloadSource) -> Vec<StepWorkload> {
        let mut v = Vec::new();
        while let Some(w) = src.next_step() {
            v.push(w);
        }
        if let Some(e) = src.take_error() {
            panic!("dist bench source failed: {e}");
        }
        v
    }

    let (single, t_single) = time_once(|| {
        let (shaped, scen) = resolve();
        drain(&mut ScenarioSource::new(shaped, scen, seed, steps))
    });
    let (chan, t_chan) = time_once(|| {
        let (shaped, scen) = resolve();
        drain(&mut DistSource::new(
            shaped,
            scen,
            seed,
            steps,
            DistPlan::channel(workers),
        ))
    });
    let (sock, t_sock) = time_once(|| {
        let (shaped, scen) = resolve();
        drain(&mut DistSource::with_transport(
            shaped,
            scen,
            seed,
            steps,
            DistPlan::socket(workers),
            // current_exe() here would be the bench binary; point the
            // transport at the real CLI for `dist-worker` children.
            Box::new(SocketTransport::new(env!("CARGO_BIN_EXE_flexmarl"))),
        ))
    });
    assert_eq!(single, chan, "channel dist output depends on placement");
    assert_eq!(single, sock, "socket dist output depends on placement");

    let per_step = |t: Duration| t.as_nanos() as f64 / steps as f64;
    let speedup = t_single.as_secs_f64() / t_chan.as_secs_f64().max(1e-9);
    println!(
        "\ndist generation ({steps} steps, {workers} workers): \
         single {:.2?}   channel {:.2?}   socket {:.2?}   channel speedup {speedup:.2}x",
        t_single, t_chan, t_sock,
    );
    let map: BTreeMap<String, Json> = [
        ("dist_steps".to_string(), Json::num(steps as f64)),
        ("dist_workers".to_string(), Json::num(workers as f64)),
        ("single_ns_per_step".to_string(), Json::num(per_step(t_single))),
        ("channel_ns_per_step".to_string(), Json::num(per_step(t_chan))),
        ("socket_ns_per_step".to_string(), Json::num(per_step(t_sock))),
        ("speedup".to_string(), Json::num(speedup)),
    ]
    .into_iter()
    .collect();
    match std::fs::write("BENCH_dist.json", Json::Obj(map).to_pretty()) {
        Ok(()) => println!("wrote BENCH_dist.json"),
        Err(e) => eprintln!("could not write BENCH_dist.json: {e}"),
    }
}

fn queue_drain(kind: QueueKind) {
    let mut q = EventQueue::with_kind(kind);
    let mut rng = Pcg64::new(1);
    for i in 0..1000u64 {
        q.push_at(rng.f64() * 100.0, i);
    }
    while let Some(e) = q.pop() {
        black_box(e);
    }
}

/// The simloop's actual pattern: a rolling horizon of near-future
/// events — push a few, pop one, repeat.
fn queue_rolling(kind: QueueKind) {
    let mut q = EventQueue::with_kind(kind);
    let mut rng = Pcg64::new(4);
    for i in 0..64u64 {
        q.push_at(rng.f64() * 3.0, i);
    }
    for i in 0..5000u64 {
        let (t, e) = q.pop().unwrap();
        black_box(e);
        q.push_at(t + rng.f64() * 3.0, i);
    }
    while let Some(e) = q.pop() {
        black_box(e);
    }
}

fn bench_event_queue(rec: &mut Recorder, t: Duration) {
    rec.add(bench("sim::EventQueue[heap] push+pop (1k events)", t, || {
        queue_drain(QueueKind::BinaryHeap)
    }));
    rec.add(bench("sim::EventQueue[calendar] push+pop (1k events)", t, || {
        queue_drain(QueueKind::Calendar)
    }));
    rec.add(bench("sim::EventQueue[heap] rolling horizon (5k)", t, || {
        queue_rolling(QueueKind::BinaryHeap)
    }));
    rec.add(bench("sim::EventQueue[calendar] rolling horizon (5k)", t, || {
        queue_rolling(QueueKind::Calendar)
    }));
}

fn bench_heap(rec: &mut Recorder, t: Duration) {
    rec.add(bench("rollout::IndexedMinHeap 10k mixed ops", t, || {
        let mut h = IndexedMinHeap::new();
        let mut rng = Pcg64::new(2);
        for i in 0..64 {
            h.insert(i, rng.below(100));
        }
        for _ in 0..10_000 {
            let id = rng.below(64) as usize;
            h.update(id, rng.below(100));
            black_box(h.peek_min());
        }
    }));
}

fn bench_manager(rec: &mut Recorder, t: Duration) {
    rec.add(bench("rollout::Manager submit+complete (1k reqs, 8 agents)", t, || {
        let mut m = RolloutManager::new(8);
        for a in 0..8 {
            m.add_instance(a, 4);
            m.add_instance(a, 4);
        }
        let mut rng = Pcg64::new(3);
        let mut active = Vec::new();
        for rid in 0..1000u64 {
            let a = rng.below(8) as usize;
            if let flexmarl::rollout::Dispatch::Started(_) = m.submit(rid, a) {
                active.push(rid);
            }
            if active.len() > 40 {
                let rid = active.swap_remove(rng.below(active.len() as u64) as usize);
                if let Some(p) = m.complete(rid) {
                    active.push(p);
                }
            }
        }
        while let Some(rid) = active.pop() {
            if let Some(p) = m.complete(rid) {
                active.push(p);
            }
        }
        black_box(m.completed_per_agent.clone());
    }));
}

fn bench_store(rec: &mut Recorder, t: Duration) {
    rec.add(bench("store::ExperienceStore insert+fill (256 samples)", t, || {
        let s = ExperienceStore::new();
        s.create_table("a", &grpo_schema());
        for i in 0..256 {
            let id = SampleId::new(i, 1, 0);
            s.insert("a", 1, id).unwrap();
            s.set_blob("a", 1, id, "prompt", Blob::Tokens(vec![1; 32])).unwrap();
            s.set_blob("a", 1, id, "response", Blob::Tokens(vec![2; 32])).unwrap();
            s.set_blob("a", 1, id, "old_logp", Blob::Floats(vec![-0.5; 32])).unwrap();
            s.set_value("a", 1, id, "reward", Value::Float(0.5)).unwrap();
            s.set_value("a", 1, id, "advantage", Value::Float(0.1)).unwrap();
        }
        black_box(s.count_ready("a", Some(1)));
    }));

    let s = ExperienceStore::new();
    s.create_table("a", &grpo_schema());
    let mut i = 0u64;
    rec.add(bench("store::fetch_ready micro-batch 16 (hot loop)", t, || {
        for _ in 0..16 {
            let id = SampleId::new(i, 1, 0);
            i += 1;
            s.insert("a", 1, id).unwrap();
            s.set_blob("a", 1, id, "prompt", Blob::Tokens(vec![1; 8])).unwrap();
            s.set_blob("a", 1, id, "response", Blob::Tokens(vec![2; 8])).unwrap();
            s.set_blob("a", 1, id, "old_logp", Blob::Floats(vec![-0.5; 8])).unwrap();
            s.set_value("a", 1, id, "reward", Value::Float(0.5)).unwrap();
            s.set_value("a", 1, id, "advantage", Value::Float(0.1)).unwrap();
        }
        let f = s.fetch_ready("a", Some(1), 16);
        let keys: Vec<_> = f.iter().map(|x| x.key).collect();
        s.complete("a", &keys).unwrap();
        black_box(keys);
    }));

    // The batched producer/consumer path the simloop actually uses:
    // one lock acquisition per group write, one per micro-batch take.
    let s = ExperienceStore::new();
    s.create_table("a", &grpo_schema());
    let mut j = 0u64;
    rec.add(bench("store::put_rows+take_batch micro-batch 16", t, || {
        let rows: Vec<PutRow> = (0..16)
            .map(|_| {
                let id = SampleId::new(j, 1, 0);
                j += 1;
                PutRow {
                    version: 1,
                    id,
                    fields: vec![
                        ("prompt", Field::Blob(Blob::Tokens(vec![1; 8]))),
                        ("response", Field::Blob(Blob::Tokens(vec![2; 8]))),
                        ("old_logp", Field::Blob(Blob::Floats(vec![-0.5; 8]))),
                        ("reward", Field::Value(Value::Float(0.5))),
                        ("advantage", Field::Value(Value::Float(0.1))),
                    ],
                }
            })
            .collect();
        s.put_rows("a", rows).unwrap();
        black_box(s.take_batch("a", Some(1), 16).len());
    }));
}

fn bench_json(rec: &mut Recorder, t: Duration) {
    if let Ok(text) = std::fs::read_to_string("artifacts/manifest.json") {
        rec.add(bench("util::json parse manifest.json", t, || {
            black_box(flexmarl::util::json::parse(&text).unwrap());
        }));
    }
}

/// The `hotpath` policy group (ISSUE 4 satellite): the simloop's
/// per-event decision points through the dyn-dispatched
/// [`PolicyBundle`] vs the same decisions as inlined capability-flag
/// reads (the retired pre-refactor path, reproduced here as the
/// reference baseline). Any dispatch overhead lands in
/// `BENCH_hotpath.json` as the delta between the two entries.
fn bench_policy_dispatch(rec: &mut Recorder, t: Duration) {
    let frameworks = Framework::all_baselines();
    let bundles: Vec<PolicyBundle> = frameworks.iter().map(|f| f.policies()).collect();

    rec.add(bench("policy::inner-step decisions, dyn bundle (4 fw × 10k)", t, || {
        let mut acc = 0u64;
        for b in &bundles {
            for _ in 0..10_000 {
                // One simulated inner step consults exactly these:
                // admission (call_done), alternation gate (maybe_train),
                // pool/contention (submit_call), balancer gate (poll).
                acc += u64::from(black_box(b.pipeline.admits_during_rollout()));
                acc += u64::from(black_box(b.pipeline.overlaps_steps()));
                acc += u64::from(black_box(b.alloc.dedicated_pools()));
                acc += u64::from(black_box(b.alloc.decode_contention_mult() != 1.0));
                acc += u64::from(black_box(b.balance.enabled()));
            }
        }
        black_box(acc);
    }));

    rec.add(bench("policy::inner-step decisions, inlined flags (4 fw × 10k)", t, || {
        let mut acc = 0u64;
        for fw in &frameworks {
            for _ in 0..10_000 {
                // The retired flag-branch equivalents, kept as the
                // dispatch-overhead reference.
                acc += u64::from(black_box(fw.async_pipeline));
                acc += u64::from(black_box(fw.one_step_async_rollout));
                acc += u64::from(black_box(fw.disaggregated));
                acc += u64::from(black_box(!fw.disaggregated));
                acc += u64::from(black_box(fw.load_balancing));
            }
        }
        black_box(acc);
    }));
}

fn bench_sim_engine(rec: &mut Recorder, t: Duration) {
    let cfg = {
        let mut c = ExperimentConfig::new(WorkloadConfig::ma(), Framework::flexmarl());
        c.steps = 1;
        c
    };
    for kind in [QueueKind::Calendar, QueueKind::BinaryHeap] {
        let opts = SimOptions {
            event_queue: kind,
            ..SimOptions::default()
        };
        let name = match kind {
            QueueKind::Calendar => "orchestrator::simulate 1 MA step (calendar)",
            QueueKind::BinaryHeap => "orchestrator::simulate 1 MA step (heap)",
        };
        rec.add(bench(name, t, || {
            black_box(try_simulate(&cfg, &opts).unwrap().total_s);
        }));
    }
}

/// The `session::` group (ISSUE 5 satellite): observer overhead on the
/// engine's event path. Three variants of the same 1-step MA
/// simulation — the monolithic no-sink `run()`, a step-drained session
/// with no sinks, and a step-drained session with a `NullSink`
/// attached (every decision point pays the dyn dispatch) — land in
/// BENCH_hotpath.json so the deltas pin the sink fan-out at ~zero.
fn bench_session(rec: &mut Recorder, t: Duration) {
    let cfg = {
        let mut c = ExperimentConfig::new(WorkloadConfig::ma(), Framework::flexmarl());
        c.steps = 1;
        c
    };
    let opts = SimOptions::default();

    rec.add(bench("session:: run() 1 MA step, no sinks (inlined loop)", t, || {
        let out = Experiment::new(cfg.clone())
            .options(opts.clone())
            .build()
            .unwrap()
            .run();
        black_box(out.total_s);
    }));

    rec.add(bench("session:: step()-drain 1 MA step, no sinks", t, || {
        let mut session = Experiment::new(cfg.clone())
            .options(opts.clone())
            .build()
            .unwrap()
            .session()
            .unwrap();
        while let Some(r) = session.step().unwrap() {
            black_box(r.e2e_s);
        }
        black_box(session.finish().total_s);
    }));

    rec.add(bench("session:: step()-drain 1 MA step, NullSink attached", t, || {
        let mut session = Experiment::new(cfg.clone())
            .options(opts.clone())
            .build()
            .unwrap()
            .session()
            .unwrap();
        session.add_sink(Box::new(NullSink));
        while let Some(r) = session.step().unwrap() {
            black_box(r.e2e_s);
        }
        black_box(session.finish().total_s);
    }));
}

fn bench_pjrt(rec: &mut Recorder) {
    let Ok(rt) = flexmarl::runtime::ModelRuntime::load("artifacts") else {
        println!("(PJRT benches skipped: run `make artifacts` first)");
        return;
    };
    let sh = rt.manifest.shapes.clone();
    let mut policy = flexmarl::runtime::policy::AgentPolicy::new(&rt, 0, 1).unwrap();
    let corpus =
        flexmarl::workload::corpus::CorpusConfig::new(rt.manifest.model.vocab, sh.t_prompt);
    let mut rng = Pcg64::new(9);
    let prompt = corpus.make_prompt(&mut rng, 0);
    let prompts: Vec<Vec<i32>> = (0..sh.b_roll).map(|_| prompt.clone()).collect();

    rec.add(bench("pjrt: prefill+16-token generate, per-token path", Duration::from_secs(3), || {
        black_box(policy.generate(&rt, &prompts, 16, 1.0).unwrap());
    }));

    rec.add(bench("pjrt: prefill+16-token generate, decode_blk path", Duration::from_secs(3), || {
        black_box(policy.generate_block(&rt, &prompts, 16, 1.0).unwrap());
    }));

    let rollouts = policy.generate(&rt, &prompts, 16, 1.0).unwrap();
    let rows: Vec<_> = rollouts
        .iter()
        .map(|ro| flexmarl::grpo::make_row(&prompt, &ro.response, &ro.logp, 0.5, sh.t_train))
        .collect();
    rec.add(bench("pjrt: grad micro-batch (b_grad rows padded)", Duration::from_secs(3), || {
        black_box(policy.grad_on_rows(&rt, &rows).unwrap());
    }));
    policy.apply(&rt, 1e-4).unwrap();

    rec.add(bench("pjrt: apply (Adam update, full param set)", Duration::from_secs(2), || {
        // Re-seed the cache each iteration so apply has work.
        policy.grad_on_rows(&rt, &rows[..1.min(rows.len())].to_vec()).unwrap();
        policy.apply(&rt, 1e-4).unwrap();
    }));
}
