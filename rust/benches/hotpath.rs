//! Hot-path micro-benches (deliverable (e) §Perf/L3): the coordinator
//! components on the request path, plus — when `artifacts/` exists — the
//! PJRT executable latencies that bound the real end-to-end run.
//!
//! `harness = false` bench on `flexmarl::util::bench` (criterion is not
//! vendored). Before/after numbers are recorded in EXPERIMENTS.md §Perf.

use flexmarl::baselines::Framework;
use flexmarl::config::{ExperimentConfig, WorkloadConfig};
use flexmarl::orchestrator::{simulate, SimOptions};
use flexmarl::rollout::{heap::IndexedMinHeap, RolloutManager};
use flexmarl::sim::EventQueue;
use flexmarl::store::{grpo_schema, Blob, ExperienceStore, SampleId, Value};
use flexmarl::util::bench::{bench, black_box};
use flexmarl::util::rng::Pcg64;
use std::time::Duration;

const T: Duration = Duration::from_millis(300);

fn main() {
    println!("════════ hot-path micro-benches ════════");
    bench_event_queue();
    bench_heap();
    bench_manager();
    bench_store();
    bench_json();
    bench_sim_engine();
    bench_pjrt();
}

fn bench_event_queue() {
    let r = bench("sim::EventQueue push+pop (1k events)", T, || {
        let mut q = EventQueue::new();
        let mut rng = Pcg64::new(1);
        for i in 0..1000u64 {
            q.push_at(rng.f64() * 100.0, i);
        }
        while let Some(e) = q.pop() {
            black_box(e);
        }
    });
    println!("{}", r.report());
}

fn bench_heap() {
    let r = bench("rollout::IndexedMinHeap 10k mixed ops", T, || {
        let mut h = IndexedMinHeap::new();
        let mut rng = Pcg64::new(2);
        for i in 0..64 {
            h.insert(i, rng.below(100));
        }
        for _ in 0..10_000 {
            let id = rng.below(64) as usize;
            h.update(id, rng.below(100));
            black_box(h.peek_min());
        }
    });
    println!("{}", r.report());
}

fn bench_manager() {
    let r = bench("rollout::Manager submit+complete (1k reqs, 8 agents)", T, || {
        let mut m = RolloutManager::new(8);
        for a in 0..8 {
            m.add_instance(a, 4);
            m.add_instance(a, 4);
        }
        let mut rng = Pcg64::new(3);
        let mut active = Vec::new();
        for rid in 0..1000u64 {
            let a = rng.below(8) as usize;
            if let flexmarl::rollout::Dispatch::Started(_) = m.submit(rid, a) {
                active.push(rid);
            }
            if active.len() > 40 {
                let rid = active.swap_remove(rng.below(active.len() as u64) as usize);
                if let Some(p) = m.complete(rid) {
                    active.push(p);
                }
            }
        }
        while let Some(rid) = active.pop() {
            if let Some(p) = m.complete(rid) {
                active.push(p);
            }
        }
        black_box(m.completed_per_agent.clone());
    });
    println!("{}", r.report());
}

fn bench_store() {
    let r = bench("store::ExperienceStore insert+fill (256 samples)", T, || {
        let s = ExperienceStore::new();
        s.create_table("a", &grpo_schema());
        for i in 0..256 {
            let id = SampleId::new(i, 1, 0);
            s.insert("a", 1, id).unwrap();
            s.set_blob("a", 1, id, "prompt", Blob::Tokens(vec![1; 32])).unwrap();
            s.set_blob("a", 1, id, "response", Blob::Tokens(vec![2; 32])).unwrap();
            s.set_blob("a", 1, id, "old_logp", Blob::Floats(vec![-0.5; 32])).unwrap();
            s.set_value("a", 1, id, "reward", Value::Float(0.5)).unwrap();
            s.set_value("a", 1, id, "advantage", Value::Float(0.1)).unwrap();
        }
        black_box(s.count_ready("a", Some(1)));
    });
    println!("{}", r.report());

    let s = ExperienceStore::new();
    s.create_table("a", &grpo_schema());
    let mut i = 0u64;
    let r = bench("store::fetch_ready micro-batch 16 (hot loop)", T, || {
        for _ in 0..16 {
            let id = SampleId::new(i, 1, 0);
            i += 1;
            s.insert("a", 1, id).unwrap();
            s.set_blob("a", 1, id, "prompt", Blob::Tokens(vec![1; 8])).unwrap();
            s.set_blob("a", 1, id, "response", Blob::Tokens(vec![2; 8])).unwrap();
            s.set_blob("a", 1, id, "old_logp", Blob::Floats(vec![-0.5; 8])).unwrap();
            s.set_value("a", 1, id, "reward", Value::Float(0.5)).unwrap();
            s.set_value("a", 1, id, "advantage", Value::Float(0.1)).unwrap();
        }
        let f = s.fetch_ready("a", Some(1), 16);
        let keys: Vec<_> = f.iter().map(|x| x.key).collect();
        s.complete("a", &keys).unwrap();
        black_box(keys);
    });
    println!("{}", r.report());
}

fn bench_json() {
    if let Ok(text) = std::fs::read_to_string("artifacts/manifest.json") {
        let r = bench("util::json parse manifest.json", T, || {
            black_box(flexmarl::util::json::parse(&text).unwrap());
        });
        println!("{}", r.report());
    }
}

fn bench_sim_engine() {
    let cfg = {
        let mut c = ExperimentConfig::new(WorkloadConfig::ma(), Framework::flexmarl());
        c.steps = 1;
        c
    };
    let opts = SimOptions::default();
    let r = bench("orchestrator::simulate 1 MA step (FlexMARL)", T, || {
        black_box(simulate(&cfg, &opts).total_s);
    });
    println!("{}", r.report());
}

fn bench_pjrt() {
    let Ok(rt) = flexmarl::runtime::ModelRuntime::load("artifacts") else {
        println!("(PJRT benches skipped: run `make artifacts` first)");
        return;
    };
    let sh = rt.manifest.shapes.clone();
    let mut policy = flexmarl::runtime::policy::AgentPolicy::new(&rt, 0, 1).unwrap();
    let corpus =
        flexmarl::workload::corpus::CorpusConfig::new(rt.manifest.model.vocab, sh.t_prompt);
    let mut rng = Pcg64::new(9);
    let prompt = corpus.make_prompt(&mut rng, 0);
    let prompts: Vec<Vec<i32>> = (0..sh.b_roll).map(|_| prompt.clone()).collect();

    let r = bench("pjrt: prefill+16-token generate, per-token path", Duration::from_secs(3), || {
        black_box(policy.generate(&rt, &prompts, 16, 1.0).unwrap());
    });
    println!("{}", r.report());

    let r = bench("pjrt: prefill+16-token generate, decode_blk path", Duration::from_secs(3), || {
        black_box(policy.generate_block(&rt, &prompts, 16, 1.0).unwrap());
    });
    println!("{}", r.report());

    let rollouts = policy.generate(&rt, &prompts, 16, 1.0).unwrap();
    let rows: Vec<_> = rollouts
        .iter()
        .map(|ro| flexmarl::grpo::make_row(&prompt, &ro.response, &ro.logp, 0.5, sh.t_train))
        .collect();
    let r = bench("pjrt: grad micro-batch (b_grad rows padded)", Duration::from_secs(3), || {
        black_box(policy.grad_on_rows(&rt, &rows).unwrap());
    });
    println!("{}", r.report());
    policy.apply(&rt, 1e-4).unwrap();

    let r = bench("pjrt: apply (Adam update, full param set)", Duration::from_secs(2), || {
        // Re-seed the cache each iteration so apply has work.
        policy.grad_on_rows(&rt, &rows[..1.min(rows.len())].to_vec()).unwrap();
        policy.apply(&rt, 1e-4).unwrap();
    });
    println!("{}", r.report());
}
