//! Serving-plane acceptance tests (DESIGN.md §13).
//!
//! The contracts pinned here:
//!
//! * **Worker-count byte identity** — every per-session JSONL stream
//!   and the whole load report are byte-identical for workers ∈
//!   {1, 2, 8}.
//! * **Standalone equivalence** — a session's captured bytes equal the
//!   same derived config run standalone through `Experiment` with a
//!   `JsonlSink`, line for line.
//! * **Typed admission edges** — queue-full and quota rejections are
//!   typed `PallasError::Admission` values with byte-stable messages;
//!   expired deadlines are counted, never silently dropped.
//! * **Scale** — the default CI mix pushes ≥500 session requests
//!   through the plane end-to-end.

use flexmarl::error::{AdmissionReject, PallasError};
use flexmarl::experiment::Experiment;
use flexmarl::orchestrator::{CaptureBuffer, JsonlSink, SimOptions};
use flexmarl::serve::sched::{self, Disposition, Request};
use flexmarl::serve::{ServeConfig, ServeOutcome, ServePlane};

/// A mix small enough to run three times in one test but busy enough
/// to exercise rejects and queueing.
fn small_mix(seed: u64) -> ServeConfig {
    let mut cfg = ServeConfig::mix("mixed", seed).unwrap();
    cfg.ticks = 12;
    cfg
}

fn run(cfg: &ServeConfig, workers: usize) -> ServeOutcome {
    ServePlane::new(cfg.clone(), workers).unwrap().run().unwrap()
}

// ---------------------------------------------------------------------------
// Determinism: worker-count independence
// ---------------------------------------------------------------------------

#[test]
fn outputs_are_byte_identical_for_any_worker_count() {
    let cfg = small_mix(2048);
    let base = run(&cfg, 1);
    assert!(base.report.completed > 0, "mix completed nothing");
    let base_report = base.report.to_json().to_pretty();
    for workers in [2, 8] {
        let out = run(&cfg, workers);
        assert_eq!(
            out.report.to_json().to_pretty(),
            base_report,
            "load report depends on workers={workers}"
        );
        assert_eq!(out.sessions.len(), base.sessions.len());
        for (a, b) in base.sessions.iter().zip(&out.sessions) {
            assert_eq!(a.seq, b.seq, "session order depends on workers={workers}");
            assert_eq!(a.jsonl, b.jsonl, "session {} bytes depend on workers={workers}", a.seq);
        }
        // The plan itself (every request's fate) is also identical.
        assert_eq!(out.schedule, base.schedule);
    }
}

#[test]
fn sessions_match_standalone_experiment_runs() {
    // Every completed session's captured stream must equal the same
    // derived config run standalone — the plane adds multiplexing, not
    // semantics.
    let cfg = small_mix(7);
    let out = run(&cfg, 4);
    assert!(!out.sessions.is_empty());
    let completed: Vec<&sched::Decision> = out
        .schedule
        .decisions
        .iter()
        .filter(|d| matches!(d.disposition, Disposition::Completed { .. }))
        .collect();
    assert_eq!(completed.len(), out.sessions.len());
    for (d, s) in completed.iter().zip(&out.sessions) {
        assert_eq!(d.request.seq, s.seq);
        assert_eq!(d.request.seed, s.seed);
        let buf = CaptureBuffer::new();
        Experiment::new(cfg.session_config(&d.request))
            .options(SimOptions::default())
            .sink(Box::new(JsonlSink::new(Box::new(buf.clone()))))
            .build()
            .unwrap()
            .try_run()
            .unwrap();
        assert_eq!(buf.contents(), s.jsonl, "session {} diverged from its standalone run", s.seq);
    }
}

// ---------------------------------------------------------------------------
// Admission edges
// ---------------------------------------------------------------------------

fn probe(seq: u64) -> Request {
    Request {
        seq,
        tenant: 0,
        arrival_tick: 0,
        deadline_tick: None,
        priority: 0,
        service_ticks: 1,
        steps: 1,
        seed: seq,
    }
}

#[test]
fn queue_full_reject_is_typed_with_stable_message() {
    let mut intake = sched::Intake::new(2);
    intake.offer(probe(0), "acme", 0, 10).unwrap();
    intake.offer(probe(1), "acme", 1, 10).unwrap();
    let (back, e) = intake.offer(probe(2), "acme", 2, 10).unwrap_err();
    assert_eq!(back.seq, 2, "the rejected request must ride back");
    assert!(matches!(
        e,
        PallasError::Admission {
            reject: AdmissionReject::QueueFull,
            limit: 2,
            ..
        }
    ));
    assert_eq!(
        e.to_string(),
        "serve: request 2 (tenant 'acme') rejected: intake queue full (cap 2)"
    );
}

#[test]
fn quota_reject_is_typed_checked_before_queue_space() {
    let mut intake = sched::Intake::new(64);
    let (_, e) = intake.offer(probe(5), "acme", 3, 3).unwrap_err();
    assert!(matches!(
        e,
        PallasError::Admission {
            reject: AdmissionReject::QuotaExceeded,
            limit: 3,
            ..
        }
    ));
    assert_eq!(
        e.to_string(),
        "serve: request 5 (tenant 'acme') rejected: tenant quota 3 outstanding sessions reached"
    );
    assert!(intake.is_empty(), "a quota reject must not occupy queue space");
}

#[test]
fn expired_deadlines_are_counted_not_dropped() {
    // One slot, immediate deadlines: whatever queues behind the
    // in-service session must expire — and every arrival still gets
    // exactly one decision.
    let mut cfg = ServeConfig::mix("steady", 5).unwrap();
    cfg.ticks = 10;
    cfg.slots = 1;
    cfg.tenants.truncate(1);
    cfg.tenants[0].deadline_ticks = Some(0);
    cfg.tenants[0].quota = 100;
    let plan = sched::plan(&cfg);
    let expired = plan
        .decisions
        .iter()
        .filter(|d| d.disposition == Disposition::Expired)
        .count();
    assert!(expired > 0, "no expiries under an immediate deadline");
    for (i, d) in plan.decisions.iter().enumerate() {
        assert_eq!(d.request.seq, i as u64, "an arrival lost its decision");
    }
    // Expired sessions are admitted-but-unserved in the report.
    let report = flexmarl::serve::report::LoadReport::build(&cfg, &plan, &[]);
    assert_eq!(report.expired, expired as u64);
    assert_eq!(report.admitted, report.completed + report.expired);
}

#[test]
fn quota_binds_under_saturation() {
    // Quota 1 on a saturated single-tenant plane: rejections must be
    // quota-typed (the queue itself never fills past the one admitted
    // outstanding session).
    let mut cfg = ServeConfig::mix("steady", 3).unwrap();
    cfg.ticks = 10;
    cfg.tenants.truncate(1);
    cfg.tenants[0].quota = 1;
    let plan = sched::plan(&cfg);
    let quota = plan
        .decisions
        .iter()
        .filter(|d| d.disposition == Disposition::RejectedQuota)
        .count();
    let full = plan
        .decisions
        .iter()
        .filter(|d| d.disposition == Disposition::RejectedQueueFull)
        .count();
    assert!(quota > 0, "quota 1 never bound under saturation");
    assert_eq!(full, 0, "queue can never fill before a quota of 1");
}

// ---------------------------------------------------------------------------
// Scale: the CI-gate mix
// ---------------------------------------------------------------------------

#[test]
fn default_mix_serves_at_least_500_session_requests() {
    // The acceptance bar: the default `serve` invocation pushes ≥500
    // session requests through admission end-to-end. Planning alone is
    // cheap, so this asserts on the full default window; execution is
    // covered by the smaller mixes above and the CI serve-smoke job.
    let cfg = ServeConfig::mix("mixed", 2048).unwrap();
    let plan = sched::plan(&cfg);
    assert!(
        plan.decisions.len() >= 500,
        "default mix submitted only {} requests",
        plan.decisions.len()
    );
    let completed = plan
        .decisions
        .iter()
        .filter(|d| matches!(d.disposition, Disposition::Completed { .. }))
        .count();
    let rejected = plan
        .decisions
        .iter()
        .filter(|d| {
            matches!(d.disposition, Disposition::RejectedQueueFull | Disposition::RejectedQuota)
        })
        .count();
    assert!(completed > 0 && rejected > 0, "default mix must exercise admission");
}
