//! Distributed-plane acceptance tests (ISSUE 10, DESIGN.md §14).
//!
//! Contracts pinned here, driving the real binary end-to-end:
//!
//! * **Byte-identity across placement** — `dist` stdout, `--json` and
//!   `--emit jsonl` match single-process `simulate` byte-for-byte for
//!   workers ∈ {1, 2, 8} on BOTH transports, including an open-loop
//!   preset whose per-step query count varies.
//! * **Worker death is survivable and invisible** — killing a worker
//!   mid-claim (socket child exits, channel thread returns) returns its
//!   shard to the unclaimed set; survivors finish the run with the
//!   exact same bytes. Losing *every* worker is a typed transport
//!   error and exit 1 — never a panic.
//! * **CLI hygiene** — `dist` refuses the single-process-only planes
//!   (`--trace`, `--resume`, …) with exit 2; `dist-worker` demands
//!   `--connect`.

use std::process::Command;

fn flexmarl(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_flexmarl"))
        .args(args)
        .output()
        .expect("spawn flexmarl")
}

fn stdout_of(out: &std::process::Output) -> &str {
    std::str::from_utf8(&out.stdout).expect("utf8 stdout")
}

fn stderr_of(out: &std::process::Output) -> &str {
    std::str::from_utf8(&out.stderr).expect("utf8 stderr")
}

fn tmp(name: &str) -> String {
    std::env::temp_dir()
        .join(format!("flexmarl_dist_{name}_{}", std::process::id()))
        .to_str()
        .unwrap()
        .to_string()
}

/// `simulate` vs `dist` with the same config flags: stdout and --json
/// must be byte-equal.
fn assert_dist_matches_simulate(cfg_flags: &[&str], transport: &str, workers: &str) {
    let ref_json = tmp(&format!("ref_{transport}_{workers}"));
    let dist_json = tmp(&format!("dist_{transport}_{workers}"));

    let mut sim_args = vec!["simulate"];
    sim_args.extend_from_slice(cfg_flags);
    sim_args.extend_from_slice(&["--json", &ref_json]);
    let sim = flexmarl(&sim_args);
    assert!(sim.status.success(), "simulate failed: {}", stderr_of(&sim));

    let mut dist_args = vec!["dist", "--transport", transport, "--workers", workers];
    dist_args.extend_from_slice(cfg_flags);
    dist_args.extend_from_slice(&["--json", &dist_json]);
    let dist = flexmarl(&dist_args);
    assert!(
        dist.status.success(),
        "dist {transport}/{workers} failed: {}",
        stderr_of(&dist)
    );

    assert_eq!(
        stdout_of(&sim),
        stdout_of(&dist),
        "stdout diverged ({transport}, {workers} workers)"
    );
    let ref_bytes = std::fs::read(&ref_json).expect("reference json");
    let dist_bytes = std::fs::read(&dist_json).expect("dist json");
    assert_eq!(
        ref_bytes, dist_bytes,
        "--json diverged ({transport}, {workers} workers)"
    );
    let _ = std::fs::remove_file(&ref_json);
    let _ = std::fs::remove_file(&dist_json);
}

const SMALL: &[&str] = &["--steps", "2", "--seed", "2048"];

#[test]
fn channel_dist_matches_simulate_for_every_worker_count() {
    for workers in ["1", "2", "8"] {
        assert_dist_matches_simulate(SMALL, "channel", workers);
    }
}

#[test]
fn socket_dist_matches_simulate_for_every_worker_count() {
    for workers in ["1", "2", "8"] {
        assert_dist_matches_simulate(SMALL, "socket", workers);
    }
}

#[test]
fn open_loop_preset_matches_on_both_transports() {
    // Per-step query counts vary under an arrival process; the
    // coordinator must size each step's shard set from the scenario.
    let flags = &["--steps", "2", "--seed", "7", "--scenario", "poisson"];
    assert_dist_matches_simulate(flags, "channel", "2");
    assert_dist_matches_simulate(flags, "socket", "2");
}

#[test]
fn emit_jsonl_streams_identically_through_the_dist_plane() {
    let mut sim_args = vec!["simulate", "--emit", "jsonl"];
    sim_args.extend_from_slice(SMALL);
    let sim = flexmarl(&sim_args);
    assert!(sim.status.success(), "{}", stderr_of(&sim));
    assert_eq!(stdout_of(&sim).lines().count(), 2, "one line per step");

    for transport in ["channel", "socket"] {
        let mut dist_args = vec![
            "dist",
            "--transport",
            transport,
            "--workers",
            "2",
            "--emit",
            "jsonl",
        ];
        dist_args.extend_from_slice(SMALL);
        let dist = flexmarl(&dist_args);
        assert!(dist.status.success(), "{}", stderr_of(&dist));
        assert_eq!(stdout_of(&sim), stdout_of(&dist), "{transport}");
    }
}

#[test]
fn killed_worker_is_invisible_in_the_output() {
    // Worker 0 dies on its first assignment; worker 1 carries the run.
    // Both transports, same bytes as the unharmed single-process run.
    let ref_out = flexmarl(&["simulate", "--steps", "2", "--seed", "2048"]);
    assert!(ref_out.status.success());
    for transport in ["channel", "socket"] {
        let out = flexmarl(&[
            "dist",
            "--transport",
            transport,
            "--workers",
            "2",
            "--worker-fail",
            "0:0",
            "--steps",
            "2",
            "--seed",
            "2048",
        ]);
        assert!(
            out.status.success(),
            "{transport}: {}",
            stderr_of(&out)
        );
        assert_eq!(stdout_of(&ref_out), stdout_of(&out), "{transport}");
    }
}

#[test]
fn losing_every_worker_is_a_typed_error_not_a_panic() {
    for transport in ["channel", "socket"] {
        let out = flexmarl(&[
            "dist",
            "--transport",
            transport,
            "--workers",
            "1",
            "--worker-fail",
            "0:0",
            "--steps",
            "2",
        ]);
        assert_eq!(out.status.code(), Some(1), "{transport}");
        let err = stderr_of(&out);
        assert!(err.contains("simulation failed"), "{transport}: {err}");
        assert!(err.contains("transport"), "{transport}: {err}");
        assert!(err.contains("cannot make progress"), "{transport}: {err}");
        assert!(!err.contains("panicked"), "{transport}: {err}");
    }
}

#[test]
fn dist_refuses_single_process_planes_with_exit_2() {
    for flag in [
        ["--trace", "t.jsonl"],
        ["--workload-mode", "lazy"],
        ["--resume", "ckpt.json"],
        ["--checkpoint-every", "1"],
    ] {
        let out = flexmarl(&["dist", flag[0], flag[1]]);
        assert_eq!(out.status.code(), Some(2), "{}", flag[0]);
        assert!(
            stderr_of(&out).contains("does not support"),
            "{}: {}",
            flag[0],
            stderr_of(&out)
        );
    }
    let out = flexmarl(&["dist", "--transport", "pigeon"]);
    assert_eq!(out.status.code(), Some(2));
    let out = flexmarl(&["dist", "--workers", "0"]);
    assert_eq!(out.status.code(), Some(2));
    let out = flexmarl(&["dist", "--workers", "2", "--worker-fail", "bogus"]);
    assert_eq!(out.status.code(), Some(2));
}

#[test]
fn dist_worker_requires_connect() {
    let out = flexmarl(&["dist-worker"]);
    assert_eq!(out.status.code(), Some(2));
    assert!(stderr_of(&out).contains("--connect"), "{}", stderr_of(&out));
}

#[test]
fn worker_bookkeeping_stays_on_stderr() {
    let mut args = vec!["dist", "--workers", "3"];
    args.extend_from_slice(SMALL);
    let out = flexmarl(&args);
    assert!(out.status.success(), "{}", stderr_of(&out));
    assert!(
        stderr_of(&out).contains("dist: 3 workers over channel transport"),
        "{}",
        stderr_of(&out)
    );
    assert!(
        !stdout_of(&out).contains("workers"),
        "worker count leaked onto stdout: {}",
        stdout_of(&out)
    );
}
