//! Streaming workload plane acceptance tests (ISSUE 7, DESIGN.md §11).
//!
//! The lazy-equivalence contract pinned here:
//!
//! * **Workload-sequence identity** — for every scenario preset × a
//!   spread of seeds, draining a lazy plan yields the exact
//!   `StepWorkload` sequence eager resolution materializes.
//! * **End-to-end byte identity** — `--workload-mode lazy` produces
//!   StepReport JSON and JSONL event streams byte-identical to eager,
//!   for every preset and every baseline framework.
//! * **Record → streaming replay** — a trace replayed through the
//!   streaming `TraceReader` path reproduces the generating run
//!   bit-for-bit, in both workload modes.
//! * **Typed mid-run failure** — a trace whose *steps* are corrupt
//!   passes lazy header validation but surfaces the eager parser's
//!   typed error text mid-run, never a panic.

use flexmarl::config::{ExperimentConfig, Framework, WorkloadConfig, WorkloadMode};
use flexmarl::experiment::Experiment;
use flexmarl::metrics::StepReport;
use flexmarl::orchestrator::{JsonlSink, SimOptions};
use flexmarl::workload::scenario;
use std::io::Write;
use std::sync::{Arc, Mutex};

fn small_cfg(fw: Framework, preset: &str) -> ExperimentConfig {
    let mut wl = WorkloadConfig::ma();
    wl.queries_per_step = 2;
    wl.group_size = 4;
    wl.scenario = preset.to_string();
    let mut cfg = ExperimentConfig::new(wl, fw);
    cfg.steps = 2;
    cfg.seed = 2048; // paper §8.1
    cfg
}

fn report_json(reports: &[StepReport]) -> String {
    reports
        .iter()
        .map(|r| r.to_json().to_pretty())
        .collect::<Vec<_>>()
        .join("\n")
}

fn with_mode(mut cfg: ExperimentConfig, mode: WorkloadMode) -> ExperimentConfig {
    cfg.workload_mode = mode;
    cfg
}

// ---------------------------------------------------------------------------
// Workload sequences: lazy == eager for every preset × seed
// ---------------------------------------------------------------------------

#[test]
fn lazy_plan_yields_eager_workload_sequence_for_every_preset_and_seed() {
    // A deterministic seed spread (LCG over a fixed start) stands in
    // for "random seeds": the property must hold for any seed.
    let mut seed = 0x2545_f491_4f6c_dd1d_u64;
    let mut seeds = vec![2048];
    for _ in 0..4 {
        seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        seeds.push(seed >> 33);
    }
    for preset in scenario::names() {
        for &s in &seeds {
            let mut cfg = small_cfg(Framework::flexmarl(), preset);
            cfg.seed = s;
            let (_, eager) = Experiment::new(cfg.clone()).build().unwrap().into_workloads();
            let (_, lazy) = Experiment::new(with_mode(cfg, WorkloadMode::Lazy))
                .build()
                .unwrap()
                .into_workloads();
            assert_eq!(eager, lazy, "{preset} seed {s}: lazy workloads diverged");
        }
    }
}

// ---------------------------------------------------------------------------
// End-to-end: reports and JSONL streams byte-identical across the grid
// ---------------------------------------------------------------------------

struct VecWriter(Arc<Mutex<Vec<u8>>>);

impl Write for VecWriter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().write(buf)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// Run `cfg` with a capturing JSONL sink; return (reports json, jsonl).
fn run_capturing(cfg: &ExperimentConfig, opts: &SimOptions) -> (String, String, f64) {
    let buf = Arc::new(Mutex::new(Vec::new()));
    let out = Experiment::new(cfg.clone())
        .options(opts.clone())
        .build()
        .unwrap()
        .with_sink(Box::new(JsonlSink::new(Box::new(VecWriter(Arc::clone(&buf))))))
        .try_run()
        .unwrap();
    let jsonl = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
    (report_json(&out.reports), jsonl, out.total_s)
}

#[test]
fn lazy_runs_byte_identical_to_eager_across_presets_and_baselines() {
    let opts = SimOptions {
        track_agents: vec![0, 1],
        ..SimOptions::default()
    };
    for fw in Framework::all_baselines() {
        for preset in scenario::names() {
            let cfg = small_cfg(fw, preset);
            let (er, ej, et) = run_capturing(&cfg, &opts);
            let (lr, lj, lt) = run_capturing(&with_mode(cfg, WorkloadMode::Lazy), &opts);
            assert_eq!(er, lr, "{} / {preset}: reports diverged", fw.name);
            assert_eq!(ej, lj, "{} / {preset}: jsonl stream diverged", fw.name);
            assert_eq!(et, lt, "{} / {preset}: total time diverged", fw.name);
        }
    }
}

// ---------------------------------------------------------------------------
// Record → replay through the streaming TraceReader
// ---------------------------------------------------------------------------

#[test]
fn streamed_trace_replay_reproduces_the_generating_run_bit_for_bit() {
    for preset in ["bursty", "flash_crowd", "diurnal"] {
        let cfg = small_cfg(Framework::flexmarl(), preset);
        let generated = Experiment::new(cfg.clone()).build().unwrap().run();

        let tr = flexmarl::workload::Trace::record(&cfg.workload, cfg.seed, cfg.steps).unwrap();
        let path = std::env::temp_dir().join(format!("flexmarl_lazy_replay_{preset}.jsonl"));
        let path = path.to_str().unwrap().to_string();
        tr.write_file(&path).unwrap();

        let mut replay_cfg = cfg.clone();
        replay_cfg.workload.trace = Some(path.clone());
        for mode in [WorkloadMode::Eager, WorkloadMode::Lazy] {
            let replayed =
                Experiment::new(with_mode(replay_cfg.clone(), mode)).build().unwrap().run();
            assert_eq!(generated.total_s, replayed.total_s, "{preset} {mode:?}");
            assert_eq!(
                report_json(&generated.reports),
                report_json(&replayed.reports),
                "{preset} {mode:?}: replay diverged from the generating run"
            );
        }
        let _ = std::fs::remove_file(&path);
    }
}

// ---------------------------------------------------------------------------
// Mid-run failure: corrupt trace steps surface the typed eager error
// ---------------------------------------------------------------------------

#[test]
fn lazy_trace_with_corrupt_step_fails_mid_run_with_the_eager_error_text() {
    let cfg = small_cfg(Framework::flexmarl(), "baseline");
    let tr = flexmarl::workload::Trace::record(&cfg.workload, cfg.seed, cfg.steps).unwrap();
    let jsonl = tr.to_jsonl();
    // Truncate mid-way through the final record: the header (and step
    // 0) stay valid, so lazy resolution accepts the file.
    let cut = &jsonl[..jsonl.trim_end().len() - 10];
    let path = std::env::temp_dir().join("flexmarl_lazy_corrupt.jsonl");
    let path = path.to_str().unwrap().to_string();
    std::fs::write(&path, cut).unwrap();

    let mut replay_cfg = cfg;
    replay_cfg.workload.trace = Some(path.clone());

    // Eager resolution rejects the file up front, at build().
    let eager_err = Experiment::new(replay_cfg.clone()).build().unwrap_err();

    // Lazy resolution accepts the header, then surfaces the *same*
    // typed error text when the engine pulls the corrupt step.
    let mut session = Experiment::new(with_mode(replay_cfg, WorkloadMode::Lazy))
        .build()
        .expect("lazy build validates only the header")
        .session()
        .unwrap();
    let lazy_err = loop {
        match session.step() {
            Ok(Some(_)) => continue,
            Ok(None) => panic!("corrupt trace must error, not exhaust cleanly"),
            Err(e) => break e,
        }
    };
    let _ = std::fs::remove_file(&path);
    assert_eq!(eager_err.to_string(), lazy_err.to_string(), "error text must match eager");
}
