//! Integration tests across modules: the simulator end-to-end over all
//! framework variants, the experience-store → pipeline contract, and the
//! paper's headline orderings. PJRT-dependent tests are gated on
//! `artifacts/` existing (run `make artifacts` first; `make test` does).

use flexmarl::baselines::{sweep, try_evaluate, Framework};
use flexmarl::config::{ExperimentConfig, ModelScale, WorkloadConfig};
use flexmarl::grpo::{group_advantages, make_row};
use flexmarl::metrics::StepReport;
use flexmarl::orchestrator::{try_simulate, SimOptions, SimOutcome};
use flexmarl::training::{swap_in_cost, swap_out_cost};

fn ma_cfg(fw: Framework, steps: usize) -> ExperimentConfig {
    let mut c = ExperimentConfig::new(WorkloadConfig::ma(), fw);
    c.steps = steps;
    c
}

/// The non-panicking entry points, unwrapped — what every test drives
/// since `simulate`/`evaluate` were deprecated.
fn simulate(cfg: &ExperimentConfig, opts: &SimOptions) -> SimOutcome {
    try_simulate(cfg, opts).unwrap()
}

fn evaluate(cfg: &ExperimentConfig, opts: &SimOptions) -> StepReport {
    try_evaluate(cfg, opts).unwrap()
}

fn opts() -> SimOptions {
    SimOptions {
        track_agents: vec![0, 1],
        ..SimOptions::default()
    }
}

// ---------------------------------------------------------------------------
// Simulator end-to-end (paper-shape assertions)
// ---------------------------------------------------------------------------

#[test]
fn table2_ordering_holds_on_both_workloads() {
    for wl in [WorkloadConfig::ma(), WorkloadConfig::ca()] {
        let mut cfg = ExperimentConfig::new(wl, Framework::flexmarl());
        cfg.steps = 3;
        let rows = sweep(&cfg, &opts());
        let e2e: Vec<f64> = rows.iter().map(|r| r.e2e_s).collect();
        // MAS-RL slowest; FlexMARL fastest; DistRL/MARTI in between.
        assert!(e2e[0] > e2e[1], "MAS-RL {} ≤ DistRL {}", e2e[0], e2e[1]);
        assert!(e2e[1] > e2e[3], "DistRL {} ≤ FlexMARL {}", e2e[1], e2e[3]);
        assert!(e2e[2] > e2e[3], "MARTI {} ≤ FlexMARL {}", e2e[2], e2e[3]);
        // Overall speedup factor is substantial (paper: 5.6–7.3×; we
        // require ≥ 3× to stay robust against recalibration).
        assert!(e2e[0] / e2e[3] > 3.0, "speedup only {}", e2e[0] / e2e[3]);
    }
}

#[test]
fn fig10_utilization_ordering() {
    let mut cfg = ma_cfg(Framework::flexmarl(), 3);
    cfg.workload = WorkloadConfig::ca();
    let rows = sweep(&cfg, &opts());
    let util: Vec<f64> = rows.iter().map(|r| r.utilization()).collect();
    assert!(util[3] > util[2] && util[2] > util[1] && util[1] > util[0],
        "CA utilization ordering violated: {util:?}");
}

#[test]
fn fig1a_long_tail_shape() {
    let out = simulate(&ma_cfg(Framework::dist_rl(), 1), &opts());
    let mut lats = out.reports[0].trajectory_latencies.clone();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = lats[lats.len() / 2];
    let max = *lats.last().unwrap();
    // Long tail: max ≫ median, and in the paper's ~170 s ballpark.
    assert!(max > 2.0 * p50, "no tail: p50 {p50} max {max}");
    assert!(max > 100.0 && max < 260.0, "max {max}");
}

#[test]
fn fig89_flexmarl_drains_core_agent_faster() {
    let core = WorkloadConfig::ma().core_agents()[0];
    let done_at = |fw: Framework| {
        let o = SimOptions {
            track_agents: vec![core],
            ..SimOptions::default()
        };
        let out = simulate(&ma_cfg(fw, 1), &o);
        let series = &out.series.processed[&core];
        let total = series.last().unwrap().1;
        series
            .iter()
            .find(|&&(_, c)| c == total)
            .map(|&(t, _)| t)
            .unwrap()
    };
    let flex = done_at(Framework::flexmarl());
    let dist = done_at(Framework::dist_rl());
    assert!(flex < dist, "FlexMARL {flex} ≥ DistRL {dist}");
}

#[test]
fn table3_async_pipeline_is_the_bigger_lever() {
    // Paper: removing async costs more than removing balancing.
    let full = evaluate(&ma_cfg(Framework::flexmarl(), 3), &opts());
    let no_lb = evaluate(&ma_cfg(Framework::flexmarl_no_balancing(), 3), &opts());
    let no_async = evaluate(&ma_cfg(Framework::flexmarl_no_async(), 3), &opts());
    assert!(no_async.e2e_s > full.e2e_s);
    assert!(no_async.e2e_s > no_lb.e2e_s, "async lever smaller than LB");
    // Sync variant shows the full-batch training tail (Fig. 7 pattern).
    assert!(no_async.train_s > 2.0 * full.train_s);
}

#[test]
fn table4_scalability_shape() {
    // More/smaller agents → faster steps and higher throughput (paper
    // Table 4 ordering: 5×32B slowest, 15×14B fastest).
    let mut results = Vec::new();
    for spec in [
        vec![(5usize, ModelScale::B32)],
        vec![(3, ModelScale::B32), (7, ModelScale::B14)],
        vec![(15, ModelScale::B14)],
    ] {
        let wl = WorkloadConfig::scale_config(&spec);
        let mut cfg = ExperimentConfig::new(wl, Framework::flexmarl());
        cfg.steps = 2;
        results.push(evaluate(&cfg, &opts()));
    }
    assert!(results[0].e2e_s > results[1].e2e_s);
    assert!(results[1].e2e_s > results[2].e2e_s);
    assert!(results[2].throughput_tps() > results[0].throughput_tps());
}

#[test]
fn fig11_swap_within_paper_budget() {
    let c = flexmarl::config::ClusterConfig::default();
    let total32 = swap_out_cost(ModelScale::B32, &c).total()
        + swap_in_cost(ModelScale::B32, &c, true).total();
    assert!(total32 < 11.0, "32B swap {total32}s > paper budget");
    let off3 = swap_out_cost(ModelScale::B3, &c).transfer_s;
    let off32 = swap_out_cost(ModelScale::B32, &c).transfer_s;
    assert!(off3 < 1.2 && off32 > 1.8 && off32 < 6.0, "{off3} {off32}");
}

#[test]
fn simulation_is_deterministic_for_paper_seed() {
    let a = simulate(&ma_cfg(Framework::flexmarl(), 2), &opts());
    let b = simulate(&ma_cfg(Framework::flexmarl(), 2), &opts());
    assert_eq!(a.total_s, b.total_s);
    for (x, y) in a.reports.iter().zip(&b.reports) {
        assert_eq!(x.e2e_s, y.e2e_s);
        assert_eq!(x.agent_calls, y.agent_calls);
        assert_eq!(x.scale_ops, y.scale_ops);
    }
}

#[test]
fn event_queue_backends_bit_identical() {
    // Acceptance gate for the calendar queue: for a fixed seed, the
    // simulation must be bit-identical under either backend — the
    // bucketed queue may only change *how fast* events pop, never
    // *which order* they pop in.
    use flexmarl::sim::QueueKind;
    for fw in [Framework::flexmarl(), Framework::mas_rl(), Framework::marti()] {
        let cfg = ma_cfg(fw, 2);
        let run = |kind: QueueKind| {
            simulate(
                &cfg,
                &SimOptions {
                    event_queue: kind,
                    ..opts()
                },
            )
        };
        let heap = run(QueueKind::BinaryHeap);
        let cal = run(QueueKind::Calendar);
        assert_eq!(heap.total_s, cal.total_s, "{}", cfg.framework.name);
        assert_eq!(heap.reports.len(), cal.reports.len());
        for (x, y) in heap.reports.iter().zip(&cal.reports) {
            assert_eq!(x.e2e_s, y.e2e_s, "{}", cfg.framework.name);
            assert_eq!(x.rollout_s, y.rollout_s);
            assert_eq!(x.train_s, y.train_s);
            assert_eq!(x.other_s, y.other_s);
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.busy_device_s, y.busy_device_s);
            assert_eq!(x.agent_calls, y.agent_calls);
            assert_eq!(x.scale_ops, y.scale_ops);
            assert_eq!(x.swap_s, y.swap_s);
            assert_eq!(x.trajectory_latencies, y.trajectory_latencies);
        }
        // Run-wide poll series must agree sample-for-sample too.
        assert_eq!(heap.series, cal.series, "{}", cfg.framework.name);
    }
}

#[test]
fn store_batch_and_unbatched_paths_agree() {
    // The micro-batch pipeline contract: a batched put_rows + take_batch
    // cycle dispatches the same samples in the same order as the
    // unbatched insert/set + fetch_ready/complete path.
    use flexmarl::store::{
        Blob, ColumnType, ExperienceStore, Field, PutRow, SampleId, Value,
    };
    let schema = [
        ("tokens", ColumnType::Float),
        ("prompt", ColumnType::Blob),
    ];
    let unbatched = ExperienceStore::new();
    unbatched.create_table("a", &schema);
    let batched = ExperienceStore::new();
    batched.create_table("a", &schema);
    for i in 0..20u64 {
        let id = SampleId::new(i, 1, 0);
        unbatched.insert("a", 1, id).unwrap();
        unbatched
            .set_value("a", 1, id, "tokens", Value::Float(i as f64))
            .unwrap();
        unbatched
            .set_blob("a", 1, id, "prompt", Blob::Tokens(vec![i as i32]))
            .unwrap();
    }
    let rows: Vec<PutRow> = (0..20u64)
        .map(|i| PutRow {
            version: 1,
            id: SampleId::new(i, 1, 0),
            fields: vec![
                ("tokens", Field::Value(Value::Float(i as f64))),
                ("prompt", Field::Blob(Blob::Tokens(vec![i as i32]))),
            ],
        })
        .collect();
    batched.put_rows("a", rows).unwrap();
    assert_eq!(batched.count_ready("a", Some(1)), 20);
    loop {
        let a = unbatched.fetch_ready("a", Some(1), 7);
        let b = batched.take_batch("a", Some(1), 7);
        assert_eq!(a.len(), b.len());
        if a.is_empty() {
            break;
        }
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.key, y.key);
            assert_eq!(x.value("tokens"), y.value("tokens"));
            // take_batch resolves payloads inline; the unbatched path
            // reads them from the arena before complete().
            let xk = match x.value("prompt") {
                Some(Value::Ref(k)) => *k,
                other => panic!("bad prompt ref {other:?}"),
            };
            assert_eq!(unbatched.blob(xk).as_ref(), y.blob("prompt"));
        }
        let keys: Vec<_> = a.iter().map(|f| f.key).collect();
        unbatched.complete("a", &keys).unwrap();
    }
    assert_eq!(unbatched.total_rows(), 0);
    assert_eq!(batched.total_rows(), 0);
    assert_eq!(batched.total_blobs(), 0);
}

// ---------------------------------------------------------------------------
// Scenario suite: generate → record → replay round-trips (per preset)
// ---------------------------------------------------------------------------

#[test]
fn scenario_roundtrip_per_preset_workloads_and_metrics_bit_identical() {
    // Acceptance gate for the scenario suite: for every preset,
    //   generate → record trace → replay trace
    // yields (a) identical StepWorkloads and (b) identical end-to-end
    // simulation metrics for the same seed.
    use flexmarl::orchestrator::resolve_workload;
    use flexmarl::workload::{scenario, Trace};
    for name in scenario::names() {
        let mut cfg = ma_cfg(Framework::flexmarl(), 2);
        cfg.workload.queries_per_step = 2;
        cfg.workload.group_size = 4;
        cfg.workload.scenario = name.to_string();

        // (a) StepWorkloads: trace JSONL round-trip == fresh generation.
        let tr = Trace::record(&cfg.workload, cfg.seed, cfg.steps).unwrap();
        let back = Trace::from_jsonl(&tr.to_jsonl()).unwrap();
        assert_eq!(tr, back, "{name}: JSONL round-trip drifted");
        let (_, generated) = resolve_workload(&cfg).unwrap();
        assert_eq!(
            back.steps, generated,
            "{name}: replayed workloads differ from generated"
        );

        // (b) end-to-end metrics: simulate generated vs replayed.
        let gen_out = simulate(&cfg, &opts());
        let path = std::env::temp_dir().join(format!("flexmarl_rt_{name}.jsonl"));
        let path = path.to_str().unwrap().to_string();
        back.write_file(&path).unwrap();
        let mut replay_cfg = cfg.clone();
        replay_cfg.workload.trace = Some(path.clone());
        let replay_out = simulate(&replay_cfg, &opts());
        let _ = std::fs::remove_file(&path);

        assert_eq!(gen_out.total_s, replay_out.total_s, "{name}");
        assert_eq!(gen_out.reports.len(), replay_out.reports.len(), "{name}");
        for (x, y) in gen_out.reports.iter().zip(&replay_out.reports) {
            assert_eq!(x.e2e_s, y.e2e_s, "{name}");
            assert_eq!(x.rollout_s, y.rollout_s, "{name}");
            assert_eq!(x.train_s, y.train_s, "{name}");
            assert_eq!(x.tokens, y.tokens, "{name}");
            assert_eq!(x.busy_device_s, y.busy_device_s, "{name}");
            assert_eq!(x.agent_calls, y.agent_calls, "{name}");
            assert_eq!(x.scale_ops, y.scale_ops, "{name}");
            assert_eq!(x.trajectory_latencies, y.trajectory_latencies, "{name}");
        }
    }
}

#[test]
fn scenario_presets_change_system_behaviour() {
    // The presets must be observably different workloads, not renames:
    // per-agent call distributions and token volumes diverge from
    // baseline (uniform kills the skew; tool_heavy stretches chains).
    let run = |name: &str| {
        let mut cfg = ma_cfg(Framework::flexmarl(), 1);
        cfg.workload.queries_per_step = 2;
        cfg.workload.group_size = 4;
        cfg.workload.scenario = name.to_string();
        simulate(&cfg, &opts()).reports.remove(0)
    };
    let base = run("baseline");
    let uniform = run("uniform");
    let tool = run("tool_heavy");
    assert_ne!(base.agent_calls, uniform.agent_calls);
    assert!(tool.tokens != base.tokens);
    // Tool-heavy chains are longer → more calls for the same queries.
    let calls = |r: &flexmarl::metrics::StepReport| r.agent_calls.iter().sum::<usize>();
    assert!(calls(&tool) > calls(&base), "{} vs {}", calls(&tool), calls(&base));
}

// ---------------------------------------------------------------------------
// Deterministic parallel sweep executor (exec + util::pool)
// ---------------------------------------------------------------------------

/// Satellite: a sweep over all seven scenario presets serializes
/// bit-identically for jobs ∈ {1, 2, 8} — the executor's whole
/// contract, asserted as a property over random base seeds and
/// framework choices.
#[test]
fn prop_sweep_serialization_bit_identical_across_job_counts() {
    use flexmarl::exec::{grid_report, run_specs_or_panic, RunGrid};
    use flexmarl::util::proptest::forall;
    use flexmarl::workload::scenario;
    forall("sweep bit-identical for jobs in {1,2,8}", 3, |rng| {
        let baselines = Framework::all_baselines();
        let fw = baselines[rng.below(baselines.len() as u64) as usize];
        let mut base = ma_cfg(fw, 1);
        base.workload.queries_per_step = 2;
        base.workload.group_size = 4;
        base.seed = rng.below(1u64 << 53);
        let grid = RunGrid {
            scenarios: scenario::owned_names(),
            ..RunGrid::default()
        };
        let specs = grid.specs(&base);
        assert_eq!(specs.len(), 7, "one spec per preset");
        let opts = SimOptions::default();
        let render = |jobs: usize| {
            let reports = run_specs_or_panic(&base, &opts, &specs, jobs);
            grid_report(&base, &specs, &reports).to_pretty()
        };
        let serial = render(1);
        for jobs in [2, 8] {
            assert_eq!(serial, render(jobs), "{} jobs={jobs}", fw.name);
        }
        // The report covers every preset, in grid order.
        for name in scenario::names() {
            assert!(serial.contains(name), "missing preset {name}");
        }
    });
}

#[test]
fn library_sweeps_match_their_serial_equivalents() {
    // sweep/scenario_sweep now fan out through the executor; their rows
    // must equal the old serial evaluate() loops exactly.
    let mut cfg = ma_cfg(Framework::flexmarl(), 1);
    cfg.workload.queries_per_step = 2;
    cfg.workload.group_size = 4;
    let rows = flexmarl::baselines::sweep_jobs(&cfg, &opts(), 4);
    for (row, fw) in rows.iter().zip(Framework::all_baselines()) {
        let mut c = cfg.clone();
        c.framework = fw;
        let serial = evaluate(&c, &opts());
        assert_eq!(row.framework, serial.framework);
        assert_eq!(row.e2e_s, serial.e2e_s);
        assert_eq!(row.tokens, serial.tokens);
        assert_eq!(row.agent_calls, serial.agent_calls);
        assert_eq!(row.scale_ops, serial.scale_ops);
    }
    let scen_rows = flexmarl::baselines::scenario_sweep_jobs(&cfg, &opts(), 4);
    for (row, name) in scen_rows.iter().zip(flexmarl::workload::scenario::names()) {
        let mut c = cfg.clone();
        c.workload.scenario = name.to_string();
        let serial = evaluate(&c, &opts());
        assert_eq!(row.scenario, name);
        assert_eq!(row.e2e_s, serial.e2e_s, "{name}");
        assert_eq!(row.tokens, serial.tokens, "{name}");
    }
}

#[test]
fn replicate_seeds_are_derived_and_decorrelated() {
    use flexmarl::exec::{derive_seed, RunGrid};
    let mut cfg = ma_cfg(Framework::flexmarl(), 1);
    cfg.workload.queries_per_step = 2;
    cfg.workload.group_size = 4;
    let grid = RunGrid {
        scenarios: vec!["baseline".to_string()],
        replicates: 3,
        ..RunGrid::default()
    };
    let specs = grid.specs(&cfg);
    assert_eq!(specs.len(), 3);
    assert_eq!(specs[0].seed, cfg.seed);
    assert_eq!(specs[1].seed, derive_seed(cfg.seed, 1));
    assert_eq!(specs[2].seed, derive_seed(cfg.seed, 2));
    // Distinct seeds → distinct workloads (replicates genuinely vary).
    let rows = flexmarl::exec::run_specs_or_panic(&cfg, &opts(), &specs, 2);
    assert!(
        rows[0].tokens != rows[1].tokens || rows[1].tokens != rows[2].tokens,
        "replicates produced identical workloads"
    );
}

#[test]
fn seed_changes_results() {
    let mut cfg = ma_cfg(Framework::flexmarl(), 1);
    let a = simulate(&cfg, &opts()).total_s;
    cfg.seed = 1;
    let b = simulate(&cfg, &opts()).total_s;
    assert_ne!(a, b);
}

// ---------------------------------------------------------------------------
// grpo + store contract (host-side pipeline math)
// ---------------------------------------------------------------------------

#[test]
fn grpo_row_assembly_roundtrip() {
    let rewards = vec![0.2, 0.8, 0.5, 0.5];
    let advs = group_advantages(&rewards);
    let prompt = vec![7i32; 16];
    let response = vec![3i32; 8];
    let logp = vec![-1.0f32; 8];
    for &a in &advs {
        let row = make_row(&prompt, &response, &logp, a as f32, 64);
        let n_masked = row.mask.iter().filter(|&&m| m == 1.0).count();
        assert_eq!(n_masked, 8);
        for (m, adv) in row.mask.iter().zip(&row.adv) {
            if *m == 0.0 {
                assert_eq!(*adv, 0.0);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// PJRT runtime (gated on artifacts)
// ---------------------------------------------------------------------------

fn artifacts_dir() -> Option<&'static str> {
    let p = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    std::path::Path::new(p)
        .join("manifest.json")
        .exists()
        .then_some(p)
}

#[test]
fn pjrt_generate_grad_apply_roundtrip() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipped: make artifacts");
        return;
    };
    use flexmarl::runtime::{policy::AgentPolicy, ModelRuntime};
    use flexmarl::util::rng::Pcg64;
    use flexmarl::workload::corpus::CorpusConfig;

    let rt = ModelRuntime::load(dir).unwrap();
    let sh = rt.manifest.shapes.clone();
    let mut policy = AgentPolicy::new(&rt, 0, 42).unwrap();
    let corpus = CorpusConfig::new(rt.manifest.model.vocab, sh.t_prompt);
    let mut rng = Pcg64::new(5);
    let prompt = corpus.make_prompt(&mut rng, 1);
    let prompts: Vec<Vec<i32>> = (0..sh.b_roll).map(|_| prompt.clone()).collect();

    let rollouts = policy.generate(&rt, &prompts, 12, 1.0).unwrap();
    assert_eq!(rollouts.len(), sh.b_roll);
    for r in &rollouts {
        assert_eq!(r.response.len(), 12);
        assert!(r.logp.iter().all(|&lp| lp <= 0.0));
        assert!(r
            .response
            .iter()
            .all(|&t| (t as usize) < rt.manifest.model.vocab));
    }
    // Candidates differ (temperature sampling).
    assert!(rollouts.windows(2).any(|w| w[0].response != w[1].response));

    let rewards: Vec<f64> = rollouts
        .iter()
        .map(|r| corpus.reward(0, 1, &r.response))
        .collect();
    let advs = group_advantages(&rewards);
    let rows: Vec<_> = rollouts
        .iter()
        .zip(&advs)
        .map(|(r, &a)| make_row(&prompt, &r.response, &r.logp, a as f32, sh.t_train))
        .collect();
    let stats = policy.grad_on_rows(&rt, &rows).unwrap();
    assert!(stats.loss.is_finite());
    assert!(stats.grad_norm > 0.0);
    // Strictly on-policy: ratio ≈ 1, KL ≈ 0 — the decode-time logprobs
    // must match grad-time log_softmax (cross-layer numerics contract).
    assert!((stats.ratio - 1.0).abs() < 1e-3, "ratio {}", stats.ratio);
    assert!(stats.kl.abs() < 1e-5, "kl {}", stats.kl);

    let v0 = policy.version;
    policy.apply(&rt, 1e-4).unwrap();
    assert_eq!(policy.version, v0 + 1);
    assert_eq!(policy.cached_micro_batches(), 0);
}

#[test]
fn pjrt_weights_blob_roundtrip() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipped: make artifacts");
        return;
    };
    use flexmarl::runtime::{policy::AgentPolicy, ModelRuntime};
    let rt = ModelRuntime::load(dir).unwrap();
    let a = AgentPolicy::new(&rt, 0, 1).unwrap();
    let mut b = AgentPolicy::new(&rt, 1, 2).unwrap();
    let blob_a = a.weights_blob().unwrap();
    assert_eq!(blob_a.len(), rt.manifest.model.num_params * 4);
    // Instance migration: agent B's replica overwrites with A's weights.
    b.load_weights_blob(&rt, &blob_a).unwrap();
    assert_eq!(b.weights_blob().unwrap(), blob_a);
    // Size mismatch rejected.
    assert!(b.load_weights_blob(&rt, &blob_a[..100]).is_err());
}

#[test]
fn pjrt_deterministic_generation_per_seed() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipped: make artifacts");
        return;
    };
    use flexmarl::runtime::{policy::AgentPolicy, ModelRuntime};
    use flexmarl::util::rng::Pcg64;
    use flexmarl::workload::corpus::CorpusConfig;
    let rt = ModelRuntime::load(dir).unwrap();
    let sh = rt.manifest.shapes.clone();
    let corpus = CorpusConfig::new(rt.manifest.model.vocab, sh.t_prompt);
    let prompt = corpus.make_prompt(&mut Pcg64::new(3), 2);
    let prompts: Vec<Vec<i32>> = (0..sh.b_roll).map(|_| prompt.clone()).collect();
    let mut p1 = AgentPolicy::new(&rt, 0, 99).unwrap();
    let mut p2 = AgentPolicy::new(&rt, 0, 99).unwrap();
    let r1 = p1.generate(&rt, &prompts, 8, 1.0).unwrap();
    let r2 = p2.generate(&rt, &prompts, 8, 1.0).unwrap();
    for (a, b) in r1.iter().zip(&r2) {
        assert_eq!(a.response, b.response);
    }
}

#[test]
fn e2e_run_loop_single_step() {
    // The full real MARL loop (rollout → store → grad → apply) for one
    // step on the compiled artifacts — the system-level smoke that all
    // layers compose (the 40/120-step runs in EXPERIMENTS.md §E2E use
    // exactly this path).
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipped: make artifacts");
        return;
    };
    use flexmarl::runtime::marl::{run_loop, E2eOptions};
    let opts = E2eOptions {
        n_queries: 1,
        chain_len: 2,
        gen_len: 8,
        temperature: 1.0,
        easy_task: false,
    };
    let logs = run_loop(dir, 2, 1, 123, 1e-4, &opts, false).unwrap();
    assert_eq!(logs.len(), 1);
    let l = &logs[0];
    assert!(l.mean_reward > 0.0 && l.mean_reward < 1.0);
    assert!(l.mean_loss.is_finite());
    assert!(l.mean_kl.abs() < 1e-4, "off-policy drift {}", l.mean_kl);
    assert_eq!(l.per_agent_reward.len(), 2);
    assert!(l.rollout_s > 0.0 && l.train_s > 0.0);
}

#[test]
fn engine_survives_degenerate_workloads() {
    // Zero-query and single-candidate configs must not deadlock the
    // event loop (empty GRPO groups, trivially-applied agents).
    for (q, g) in [(1usize, 1usize), (1, 2), (2, 1)] {
        let mut cfg = ma_cfg(Framework::flexmarl(), 1);
        cfg.workload.queries_per_step = q;
        cfg.workload.group_size = g;
        let out = simulate(&cfg, &opts());
        assert!(out.total_s > 0.0, "q={q} g={g}");
        assert!(out.reports[0].tokens > 0.0);
    }
}

#[test]
fn engine_scales_to_many_agents_and_steps() {
    // 15-agent ensemble over 3 steps completes and stays deterministic.
    let wl = WorkloadConfig::scale_config(&[(15, ModelScale::B14)]);
    let mut cfg = ExperimentConfig::new(wl, Framework::flexmarl());
    cfg.steps = 3;
    let a = simulate(&cfg, &opts()).total_s;
    let b = simulate(&cfg, &opts()).total_s;
    assert_eq!(a, b);
}
