//! Streaming Session API acceptance tests (ISSUE 5).
//!
//! The contracts pinned here:
//!
//! * **Golden-grid byte identity** — driving `Session::step()` to
//!   exhaustion serializes StepReports byte-identical to
//!   `Experiment::run()` for all 7 scenario presets × 4 baseline
//!   frameworks at the paper seed.
//! * **Observation is free of side effects** — attaching sinks cannot
//!   change a bit of the simulation.
//! * **Early stop** — a budget sink halts mid-run with a well-formed,
//!   typed partial `SimOutcome` (no panics on any public path).
//! * **TraceSink round-trip** — trace capture through the observer API
//!   reproduces `Trace::record` bit-for-bit and replays bit-identically.
//! * **JsonlSink streaming** — the streamed lines equal the batch
//!   reports' JSON, line for line.

use flexmarl::config::{ExperimentConfig, Framework, WorkloadConfig};
use flexmarl::error::PallasError;
use flexmarl::experiment::Experiment;
use flexmarl::metrics::StepReport;
use flexmarl::orchestrator::{
    BudgetSink, ControlFlow, EngineEvent, EventSink, JsonlSink, NullSink, ProgressSink,
    SimOptions, TraceSink,
};
use flexmarl::workload::scenario;
use std::io::Write;
use std::sync::{Arc, Mutex};

fn small_cfg(fw: Framework, preset: &str) -> ExperimentConfig {
    let mut wl = WorkloadConfig::ma();
    wl.queries_per_step = 2;
    wl.group_size = 4;
    wl.scenario = preset.to_string();
    let mut cfg = ExperimentConfig::new(wl, fw);
    cfg.steps = 2;
    cfg.seed = 2048; // paper §8.1
    cfg
}

fn report_json(reports: &[StepReport]) -> String {
    reports
        .iter()
        .map(|r| r.to_json().to_pretty())
        .collect::<Vec<_>>()
        .join("\n")
}

fn drain(cfg: &ExperimentConfig, opts: &SimOptions) -> flexmarl::orchestrator::SimOutcome {
    let mut session = Experiment::new(cfg.clone())
        .options(opts.clone())
        .build()
        .unwrap()
        .session()
        .unwrap();
    while session.step().unwrap().is_some() {}
    session.finish()
}

// ---------------------------------------------------------------------------
// Golden grid: session-driven == monolithic, byte for byte
// ---------------------------------------------------------------------------

#[test]
fn session_drain_is_byte_identical_to_run_across_golden_grid() {
    // 4 baselines × 7 presets at the paper seed: the streamed report
    // sequence, the total time, and the run series must all match the
    // batch run exactly.
    let opts = SimOptions {
        track_agents: vec![0, 1],
        ..SimOptions::default()
    };
    for fw in Framework::all_baselines() {
        for preset in scenario::names() {
            let cfg = small_cfg(fw, preset);
            let batch = Experiment::new(cfg.clone())
                .options(opts.clone())
                .build()
                .unwrap()
                .run();
            let streamed = drain(&cfg, &opts);
            assert_eq!(
                report_json(&batch.reports),
                report_json(&streamed.reports),
                "{} / {preset}: session reports diverged from run()",
                fw.name
            );
            assert_eq!(batch.total_s, streamed.total_s, "{} / {preset}", fw.name);
            assert_eq!(batch.series, streamed.series, "{} / {preset}", fw.name);
            assert!(streamed.stop.is_none(), "{} / {preset}", fw.name);
        }
    }
}

#[test]
fn session_yields_reports_incrementally_and_in_order() {
    let cfg = small_cfg(Framework::flexmarl(), "baseline");
    let mut session = Experiment::new(cfg).build().unwrap().session().unwrap();
    assert_eq!(session.steps_completed(), 0);
    assert!(!session.is_done());

    let r0 = session.step().unwrap().expect("step 0");
    assert_eq!(session.steps_completed(), 1);
    let t_after_first = session.now();
    assert!(t_after_first > 0.0);

    let r1 = session.step().unwrap().expect("step 1");
    assert!(session.now() >= t_after_first, "virtual time ran backwards");
    assert!(r0.e2e_s > 0.0 && r1.e2e_s > 0.0);

    assert!(session.step().unwrap().is_none(), "only two steps exist");
    assert!(session.is_done());
    assert!(session.step().unwrap().is_none(), "None is sticky");
    let out = session.finish();
    assert_eq!(out.reports.len(), 2);
    assert!(out.stop.is_none());
}

#[test]
fn evaluate_matches_session_drain_aggregation() {
    // The paper-table aggregate computed from a drained session equals
    // Experiment::evaluate — including MARTI, whose E2E is amortized
    // over the run.
    for fw in [Framework::flexmarl(), Framework::marti(), Framework::mas_rl()] {
        let cfg = small_cfg(fw, "core_skew");
        let via_evaluate = Experiment::new(cfg.clone()).build().unwrap().evaluate();
        let exp = Experiment::new(cfg).build().unwrap();
        let overlaps = exp.policies().pipeline.overlaps_steps();
        let mut session = exp.session().unwrap();
        while session.step().unwrap().is_some() {}
        let via_session = session.finish().evaluate(overlaps).unwrap();
        assert_eq!(
            via_evaluate.to_json().to_pretty(),
            via_session.to_json().to_pretty(),
            "{}",
            fw.name
        );
    }
}

// ---------------------------------------------------------------------------
// Sinks observe but never perturb
// ---------------------------------------------------------------------------

/// A sink that subscribes to everything and counts what it saw.
#[derive(Default)]
struct CountingSink {
    started: usize,
    finished: usize,
    micro_batches: usize,
    migrations: usize,
    scaler_polls: usize,
    swaps: usize,
    phase_switches: usize,
}

struct SharedCounting(Arc<Mutex<CountingSink>>);

impl EventSink for SharedCounting {
    fn on_event(&mut self, _t: f64, ev: &EngineEvent<'_>) -> ControlFlow {
        let mut c = self.0.lock().unwrap();
        match ev {
            EngineEvent::StepStarted { .. } => c.started += 1,
            EngineEvent::StepFinished { .. } => c.finished += 1,
            EngineEvent::MicroBatchAdmitted { .. } => c.micro_batches += 1,
            EngineEvent::MigrationPlanned { .. } => c.migrations += 1,
            EngineEvent::ScalerDecision { .. } => c.scaler_polls += 1,
            EngineEvent::SwapIn { .. } | EngineEvent::SwapOut { .. } => c.swaps += 1,
            EngineEvent::PhaseSwitch { .. } => c.phase_switches += 1,
            _ => {}
        }
        ControlFlow::Continue
    }
}

#[test]
fn sinks_observe_without_perturbing_the_simulation() {
    // NullSink + ProgressSink (buffered) + a counting sink attached:
    // the outcome must be byte-identical to the bare run, and the
    // counters prove the events actually flowed.
    struct VecWriter(Arc<Mutex<Vec<u8>>>);
    impl Write for VecWriter {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }
    let cfg = small_cfg(Framework::flexmarl(), "core_skew");
    let bare = Experiment::new(cfg.clone()).build().unwrap().run();

    let counts = Arc::new(Mutex::new(CountingSink::default()));
    let progress_buf = Arc::new(Mutex::new(Vec::new()));
    let observed = Experiment::new(cfg.clone())
        .sink(Box::new(NullSink))
        .sink(Box::new(ProgressSink::new(
            cfg.steps,
            Box::new(VecWriter(Arc::clone(&progress_buf))),
        )))
        .sink(Box::new(SharedCounting(Arc::clone(&counts))))
        .build()
        .unwrap()
        .run();

    assert_eq!(report_json(&bare.reports), report_json(&observed.reports));
    assert_eq!(bare.total_s, observed.total_s);
    assert_eq!(bare.series, observed.series);

    let c = counts.lock().unwrap();
    assert_eq!(c.started, 2, "one StepStarted per step");
    assert_eq!(c.finished, 2, "one StepFinished per step");
    assert!(c.micro_batches > 0, "pipeline admitted no micro batches");
    assert!(c.scaler_polls > 0, "scaler never polled");
    // Every counted scale op corresponds to one observed
    // MigrationPlanned event — the observer saw exactly what the
    // metrics recorded.
    let scale_ops_total: usize = bare.reports.iter().map(|r| r.scale_ops).sum();
    assert_eq!(c.migrations, scale_ops_total, "migration events != scale_ops");
    assert!(c.swaps > 0, "agent-centric allocation should swap");
    let progress = String::from_utf8(progress_buf.lock().unwrap().clone()).unwrap();
    assert!(progress.contains("step 1/2"), "{progress}");
    assert!(progress.contains("step 2/2"), "{progress}");
}

#[test]
fn phase_switch_events_fire_for_colocated_alternation() {
    // MAS-RL: offload/onload at every phase boundary — both directions
    // must be observable.
    struct Phases(Arc<Mutex<Vec<(usize, bool)>>>);
    impl EventSink for Phases {
        fn on_event(&mut self, _t: f64, ev: &EngineEvent<'_>) -> ControlFlow {
            if let EngineEvent::PhaseSwitch { step, to_train } = ev {
                self.0.lock().unwrap().push((*step, *to_train));
            }
            ControlFlow::Continue
        }
    }
    let cfg = small_cfg(Framework::mas_rl(), "baseline");
    let seen = Arc::new(Mutex::new(Vec::new()));
    let out = Experiment::new(cfg)
        .sink(Box::new(Phases(Arc::clone(&seen))))
        .build()
        .unwrap()
        .run();
    assert_eq!(out.reports.len(), 2);
    let seen = seen.lock().unwrap();
    // Step 0: to-train and (because a step follows) to-rollout; step 1
    // is last, so only its to-train switch fires.
    assert_eq!(*seen, vec![(0, true), (0, false), (1, true)]);
}

// ---------------------------------------------------------------------------
// Early stop
// ---------------------------------------------------------------------------

#[test]
fn budget_sink_halts_mid_run_with_well_formed_partial_outcome() {
    let cfg = {
        let mut c = small_cfg(Framework::flexmarl(), "baseline");
        c.steps = 3;
        c
    };
    let full = Experiment::new(cfg.clone()).build().unwrap().run();
    assert_eq!(full.reports.len(), 3);

    let mut session = Experiment::new(cfg.clone())
        .sink(Box::new(BudgetSink::new().max_steps(1)))
        .build()
        .unwrap()
        .session()
        .unwrap();
    let first = session.step().unwrap().expect("first step completes");
    assert!(session.step().unwrap().is_none(), "budget cut the run");
    let stop = session.stop_info().expect("stop recorded").clone();
    assert_eq!(stop.steps_completed, 1);
    assert!(stop.t > 0.0);
    let partial = session.finish();
    assert_eq!(partial.reports.len(), 1);
    assert_eq!(partial.stop, Some(stop));
    assert!(partial.total_s > 0.0);
    assert!(partial.total_s < full.total_s, "stopped run ran to the end");
    // The completed step is bit-identical to the full run's first step.
    assert_eq!(
        first.to_json().to_pretty(),
        full.reports[0].to_json().to_pretty()
    );
    // Partial outcomes aggregate cleanly too.
    assert!(partial.evaluate(false).is_some());
}

#[test]
fn sim_time_budget_stops_before_first_step_without_panicking() {
    // Stop almost immediately: no step completes; the outcome is empty
    // but typed — no panic on any public session path.
    let cfg = small_cfg(Framework::flexmarl(), "baseline");
    let mut session = Experiment::new(cfg.clone())
        .sink(Box::new(BudgetSink::new().max_sim_s(0.5)))
        .build()
        .unwrap()
        .session()
        .unwrap();
    assert!(session.step().unwrap().is_none());
    let out = session.finish();
    assert_eq!(out.reports.len(), 0);
    let stop = out.stop.expect("stop recorded");
    assert_eq!(stop.steps_completed, 0);
    assert!(out.evaluate(false).is_none(), "nothing to aggregate");

    // The evaluate convenience reports the same condition as a typed
    // EmptyRun (NOT InvalidConfig: the config is fine, the run was
    // merely truncated).
    let err = Experiment::new(cfg)
        .sink(Box::new(BudgetSink::new().max_sim_s(0.5)))
        .build()
        .unwrap()
        .try_evaluate()
        .unwrap_err();
    assert_eq!(err, PallasError::EmptyRun);
    assert!(err.to_string().contains("no steps"), "{err}");
}

#[test]
fn token_budget_stops_after_enough_generation() {
    let cfg = {
        let mut c = small_cfg(Framework::flexmarl(), "baseline");
        c.steps = 3;
        c
    };
    let full = Experiment::new(cfg.clone()).build().unwrap().run();
    let step_tokens = full.reports[0].tokens;
    // Budget = just over one step's tokens → stops after step 1's
    // report lands (token counts are checked at step boundaries).
    let mut session = Experiment::new(cfg)
        .sink(Box::new(BudgetSink::new().max_tokens(step_tokens + 1.0)))
        .build()
        .unwrap()
        .session()
        .unwrap();
    let mut n = 0;
    while session.step().unwrap().is_some() {
        n += 1;
    }
    assert_eq!(n, 2, "token budget should bite after the second step");
    assert!(session.stop_info().is_some());
}

// ---------------------------------------------------------------------------
// TraceSink: recording as an observer, bit-for-bit
// ---------------------------------------------------------------------------

#[test]
fn trace_sink_matches_trace_record_bit_for_bit() {
    use flexmarl::workload::Trace;
    for preset in scenario::names() {
        let cfg = small_cfg(Framework::flexmarl(), preset);
        let exp = Experiment::new(cfg.clone()).build().unwrap();
        // TraceSink is built against the *resolved* config (canonical
        // scenario name, shaped agents).
        let (sink, handle) = TraceSink::new(exp.config());
        let resolved_workload = exp.config().workload.clone();
        let out = exp.with_sink(Box::new(sink)).run();
        assert_eq!(out.reports.len(), 2, "{preset}");

        let captured = handle.trace().unwrap();
        let direct = Trace::record(&resolved_workload, cfg.seed, cfg.steps).unwrap();
        // PartialEq on f64 fields is exact: bit-for-bit, not approx.
        assert_eq!(captured, direct, "{preset}: TraceSink drifted from Trace::record");
        assert_eq!(captured.to_jsonl(), direct.to_jsonl(), "{preset}");

        // And the captured trace replays bit-identically.
        let path = std::env::temp_dir().join(format!("flexmarl_sink_trace_{preset}.jsonl"));
        let path = path.to_str().unwrap().to_string();
        captured.write_file(&path).unwrap();
        let mut replay_cfg = cfg.clone();
        replay_cfg.workload.trace = Some(path.clone());
        let replayed = Experiment::new(replay_cfg).build().unwrap().run();
        let _ = std::fs::remove_file(&path);
        assert_eq!(out.total_s, replayed.total_s, "{preset}");
        assert_eq!(
            report_json(&out.reports),
            report_json(&replayed.reports),
            "{preset}"
        );
    }
}

#[test]
fn trace_sink_on_a_stopped_run_captures_only_started_steps() {
    let cfg = {
        let mut c = small_cfg(Framework::flexmarl(), "baseline");
        c.steps = 3;
        c
    };
    let exp = Experiment::new(cfg).build().unwrap();
    let (sink, handle) = TraceSink::new(exp.config());
    let out = exp
        .with_sink(Box::new(sink))
        .with_sink(Box::new(BudgetSink::new().max_steps(1)))
        .run();
    assert_eq!(out.reports.len(), 1);
    // FlexMARL starts step s+1 only after step s completes, so at most
    // the next step began before the stop landed.
    let n = handle.steps_recorded();
    assert!((1..=2).contains(&n), "captured {n} steps");
    // Partial capture is still a valid (replayable) trace prefix.
    let tr = handle.trace().unwrap();
    assert_eq!(tr.steps.len(), n);
}

// ---------------------------------------------------------------------------
// JsonlSink: streamed lines == batch reports
// ---------------------------------------------------------------------------

#[test]
fn jsonl_sink_streams_exactly_the_batch_report_lines() {
    struct VecWriter(Arc<Mutex<Vec<u8>>>);
    impl Write for VecWriter {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }
    for preset in ["core_skew", "bursty"] {
        let cfg = small_cfg(Framework::flexmarl(), preset);
        let batch = Experiment::new(cfg.clone()).build().unwrap().run();
        let expected: String = batch
            .reports
            .iter()
            .map(|r| format!("{}\n", r.to_json().to_string()))
            .collect();
        let buf = Arc::new(Mutex::new(Vec::new()));
        let _ = Experiment::new(cfg)
            .sink(Box::new(JsonlSink::new(Box::new(VecWriter(Arc::clone(&buf))))))
            .build()
            .unwrap()
            .run();
        let streamed = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
        assert_eq!(streamed, expected, "{preset}");
    }
}

// ---------------------------------------------------------------------------
// Typed errors on the session surface
// ---------------------------------------------------------------------------

#[test]
fn session_surfaces_build_errors_typed() {
    let err = Experiment::new(small_cfg(Framework::flexmarl(), "no_such_preset"))
        .build()
        .unwrap_err();
    assert_eq!(err, PallasError::UnknownScenario("no_such_preset".into()));
}

#[test]
fn event_budget_error_is_typed_and_displays_like_the_old_panic() {
    // The livelock guard itself needs ~1M events to trip — far beyond
    // test scale — so pin the typed variant's shape and Display here
    // (simloop can only construct it through the same formatter).
    let e = PallasError::EventBudget {
        t: 3.25,
        histogram: vec![("StartStep", 1), ("CallDone", 999_999)],
    };
    let msg = e.to_string();
    assert!(
        msg.starts_with("event-budget exceeded (livelock?) at t=3.25:"),
        "{msg}"
    );
    assert!(msg.contains("CallDone"), "{msg}");
    // It is a std error like every other PallasError.
    let _: &dyn std::error::Error = &e;
}
