//! Checkpoint/resume plane acceptance tests (DESIGN.md §12).
//!
//! The contracts pinned here:
//!
//! * **Byte-identical resume** — a run snapshotted after any step k and
//!   resumed onto a freshly built session yields the remaining reports,
//!   the total virtual time, and the run series byte-for-byte equal to
//!   the uninterrupted run — for an open-loop arrival scenario and for
//!   a chaos-faulted run (the two CI presets).
//! * **Snapshot idempotence** — snapshot → restore → snapshot encodes
//!   to the identical checkpoint text.
//! * **Periodic checkpointing** — `.checkpoint_every(n)` writes
//!   `<dir>/ckpt.json` crash-consistently during both `step()` and
//!   `run_to_end()` drains, and the file resumes.
//! * **Typed rejection** — corrupt, truncated, stale-format-version,
//!   and config-fingerprint-mismatched checkpoints all fail with
//!   `PallasError::Checkpoint`, never a panic or garbage state.

use flexmarl::config::{ExperimentConfig, Framework, WorkloadConfig};
use flexmarl::error::PallasError;
use flexmarl::experiment::Experiment;
use flexmarl::fault::preset;
use flexmarl::metrics::StepReport;
use flexmarl::orchestrator::{Session, SimOptions, SimOutcome};

const STEPS: usize = 4;

/// The two acceptance presets: one open-loop arrival scenario, one
/// closed-loop scenario under the chaos fault plan.
fn acceptance_cfgs() -> Vec<(String, ExperimentConfig)> {
    let mut open_loop = small_cfg("poisson");
    open_loop.faults = Default::default();
    let mut faulted = small_cfg("core_skew");
    faulted.faults = preset("chaos").unwrap();
    vec![
        ("poisson (open-loop)".to_string(), open_loop),
        ("core_skew + chaos faults".to_string(), faulted),
    ]
}

fn small_cfg(scenario: &str) -> ExperimentConfig {
    let mut wl = WorkloadConfig::ma();
    wl.queries_per_step = 2;
    wl.group_size = 4;
    wl.scenario = scenario.to_string();
    let mut cfg = ExperimentConfig::new(wl, Framework::flexmarl());
    cfg.steps = STEPS;
    cfg.seed = 2048; // paper §8.1
    cfg
}

fn opts() -> SimOptions {
    SimOptions {
        track_agents: vec![0, 1],
        ..SimOptions::default()
    }
}

fn build(cfg: &ExperimentConfig) -> Experiment {
    Experiment::new(cfg.clone())
        .options(opts())
        .build()
        .unwrap()
}

fn fresh_session(cfg: &ExperimentConfig) -> Session {
    build(cfg).session().unwrap()
}

/// Full-fidelity serialization of a report list — `to_ckpt_json` keeps
/// every field bit-exact, so string equality is byte identity.
fn reports_text(reports: &[StepReport]) -> String {
    reports
        .iter()
        .map(|r| r.to_ckpt_json().to_string())
        .collect::<Vec<_>>()
        .join("\n")
}

fn assert_outcomes_identical(a: &SimOutcome, b: &SimOutcome, label: &str) {
    assert_eq!(
        reports_text(&a.reports),
        reports_text(&b.reports),
        "{label}: resumed reports diverged"
    );
    assert_eq!(
        a.total_s.to_bits(),
        b.total_s.to_bits(),
        "{label}: total_s diverged"
    );
    assert_eq!(a.series, b.series, "{label}: run series diverged");
}

/// A scratch path under the OS temp dir, unique per (process, tag) so
/// parallel test binaries never collide.
fn scratch(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("flexmarl_ckpt_it_{}_{tag}", std::process::id()))
        .to_str()
        .unwrap()
        .to_string()
}

// ---------------------------------------------------------------------------
// The core contract: kill at any step, resume, byte-identical output
// ---------------------------------------------------------------------------

#[test]
fn resume_from_any_step_is_byte_identical_to_uninterrupted_run() {
    for (label, cfg) in acceptance_cfgs() {
        let mut full = fresh_session(&cfg);
        while full.step().unwrap().is_some() {}
        let full = full.finish();
        assert_eq!(full.reports.len(), STEPS, "{label}");

        for k in 1..STEPS {
            // "Crash" after step k: all that survives is the snapshot.
            let mut victim = fresh_session(&cfg);
            for _ in 0..k {
                victim.step().unwrap().expect("mid-run step");
            }
            let payload = victim.snapshot();
            drop(victim);

            let mut resumed = build(&cfg).resume(&payload, "").unwrap();
            assert_eq!(resumed.steps_completed(), k, "{label} k={k}");
            while resumed.step().unwrap().is_some() {}
            let resumed = resumed.finish();
            assert_outcomes_identical(&full, &resumed, &format!("{label} k={k}"));

            // The paper-table aggregate is identical too.
            let overlaps = build(&cfg).policies().pipeline.overlaps_steps();
            assert_eq!(
                full.evaluate(overlaps).unwrap().to_json().to_pretty(),
                resumed.evaluate(overlaps).unwrap().to_json().to_pretty(),
                "{label} k={k}: evaluate() diverged"
            );
        }
    }
}

#[test]
fn snapshot_restore_snapshot_is_identity() {
    for (label, cfg) in acceptance_cfgs() {
        let mut s = fresh_session(&cfg);
        s.step().unwrap().unwrap();
        s.step().unwrap().unwrap();
        let first = s.snapshot();
        let restored = fresh_session(&cfg).restore(&first, "").unwrap();
        let second = restored.snapshot();
        assert_eq!(
            flexmarl::ckpt::encode(&first),
            flexmarl::ckpt::encode(&second),
            "{label}: re-snapshot of a restored session drifted"
        );
    }
}

#[test]
fn resume_of_a_completed_run_yields_nothing_more() {
    let cfgs = acceptance_cfgs();
    let (_, cfg) = &cfgs[0];
    let mut s = fresh_session(cfg);
    while s.step().unwrap().is_some() {}
    let payload = s.snapshot();
    let full = s.finish();

    let mut resumed = build(cfg).resume(&payload, "").unwrap();
    assert_eq!(resumed.steps_completed(), STEPS);
    assert!(resumed.step().unwrap().is_none(), "no steps left to run");
    assert_outcomes_identical(&full, &resumed.finish(), "completed-run resume");
}

// ---------------------------------------------------------------------------
// Periodic checkpoint files
// ---------------------------------------------------------------------------

#[test]
fn periodic_checkpointing_writes_a_resumable_file() {
    let dir = scratch("periodic");
    std::fs::create_dir_all(&dir).unwrap();
    let cfgs = acceptance_cfgs();
    let (_, cfg) = &cfgs[1];

    // run_to_end drains without going through step() — it must
    // checkpoint too.
    let full = Experiment::new(cfg.clone())
        .options(opts())
        .checkpoint_every(1)
        .checkpoint_dir(&dir)
        .build()
        .unwrap()
        .session()
        .unwrap()
        .run_to_end()
        .unwrap();

    let ckpt_path = format!("{dir}/ckpt.json");
    assert!(
        std::path::Path::new(&ckpt_path).exists(),
        "periodic checkpoint file missing"
    );
    // No temp litter from the atomic-rename protocol.
    assert!(
        !std::path::Path::new(&format!("{ckpt_path}.tmp.{}", std::process::id())).exists()
    );

    // The last checkpoint (after the final step) resumes to the same
    // outcome. Resume with a *plain* config — the checkpoint settings
    // are excluded from the fingerprint, so the resuming process does
    // not have to re-enable checkpointing.
    let resumed = build(cfg).resume_file(&ckpt_path).unwrap().finish();
    assert_outcomes_identical(&full, &resumed, "periodic-file resume");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn checkpoint_every_zero_is_rejected_at_build() {
    let err = Experiment::new(small_cfg("baseline"))
        .checkpoint_every(0)
        .build()
        .map(|_| ())
        .unwrap_err();
    assert!(
        matches!(err, PallasError::InvalidConfig(_)),
        "expected InvalidConfig, got {err:?}"
    );
    assert!(err.to_string().contains("checkpoint.every"), "{err}");
}

// ---------------------------------------------------------------------------
// Typed rejection of bad checkpoints
// ---------------------------------------------------------------------------

#[test]
fn config_fingerprint_mismatch_is_rejected() {
    let cfgs = acceptance_cfgs();
    let (_, cfg) = &cfgs[0];
    let mut s = fresh_session(cfg);
    s.step().unwrap().unwrap();
    let payload = s.snapshot();

    // Same payload, different seed: restoring would silently splice two
    // unrelated runs together — must be refused.
    let mut other = cfg.clone();
    other.seed = 7;
    let err = build(&other).resume(&payload, "ck.json").unwrap_err();
    assert!(
        matches!(err, PallasError::Checkpoint { .. }),
        "expected Checkpoint error, got {err:?}"
    );
    let msg = err.to_string();
    assert!(msg.contains("fingerprint"), "{msg}");
    assert!(msg.contains("ck.json"), "{msg}");
}

#[test]
fn corrupt_truncated_and_stale_files_are_rejected_via_resume_file() {
    let cfgs = acceptance_cfgs();
    let (_, cfg) = &cfgs[0];
    let mut s = fresh_session(cfg);
    s.step().unwrap().unwrap();

    let path = scratch("reject.json");
    s.save(&path).unwrap();
    let good = std::fs::read_to_string(&path).unwrap();

    // Bit-flip inside the payload: checksum rejection.
    let flipped = {
        let idx = good.len() - 10;
        let mut bytes = good.clone().into_bytes();
        bytes[idx] = if bytes[idx] == b'0' { b'1' } else { b'0' };
        String::from_utf8(bytes).unwrap()
    };
    std::fs::write(&path, &flipped).unwrap();
    let err = build(cfg).resume_file(&path).unwrap_err();
    assert!(matches!(err, PallasError::Checkpoint { .. }), "{err:?}");
    assert!(err.to_string().contains("checksum mismatch"), "{err}");

    // Torn tail: the payload line cut mid-write.
    std::fs::write(&path, &good[..good.len() - 25]).unwrap();
    let err = build(cfg).resume_file(&path).unwrap_err();
    assert!(err.to_string().contains("truncated"), "{err}");

    // Stale format version.
    std::fs::write(&path, good.replacen("\"version\":1", "\"version\":99", 1)).unwrap();
    let err = build(cfg).resume_file(&path).unwrap_err();
    assert!(
        err.to_string()
            .contains("unsupported checkpoint format version 99"),
        "{err}"
    );

    // Not a checkpoint at all.
    std::fs::write(&path, "{\"hello\":1}\n{}\n").unwrap();
    let err = build(cfg).resume_file(&path).unwrap_err();
    assert!(err.to_string().contains("bad magic"), "{err}");

    // Missing file: typed File error, not a panic.
    std::fs::remove_file(&path).unwrap();
    let err = build(cfg).resume_file(&path).unwrap_err();
    assert!(matches!(err, PallasError::File { .. }), "{err:?}");
}
