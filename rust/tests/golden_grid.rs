//! Golden-grid equivalence for the policy-object engine (ISSUE 4's
//! hard constraint): for every baseline framework × every scenario
//! preset at the paper seed, the engine must serialize byte-identical
//! `StepReport` JSON whether its policies were
//!
//!  * derived from the capability flags (`Framework::policies()` — the
//!    path `try_simulate`/`baselines`/`exec` all take), or
//!  * assembled *by hand* from the concrete policy impls, mirroring the
//!    retired flag-branch logic one trait at a time.
//!
//! Together with the CI scenario-matrix and sweep-determinism byte
//! diffs (which pin the flag-derived path across builds), this pins the
//! whole refactor: flags → bundle → engine is the identity the old
//! inline branches computed.
//!
//! The file also demonstrates the acceptance criterion that a *new*
//! framework registers as a policy bundle without touching
//! `orchestrator/simloop.rs`: a mixed-policy hybrid runs end-to-end
//! through the same engine.

use flexmarl::config::{ExperimentConfig, Framework, WorkloadConfig};
use flexmarl::experiment::Experiment;
use flexmarl::orchestrator::{try_simulate, SimOptions};
use flexmarl::policy::{
    AgentCentricAlloc, AllocPolicy, BalancePolicy, ColocatedOnDemand, ColocatedStatic,
    DisaggregatedStatic, HierarchicalBalance, MicroBatchAsync, OneStepAsync, ParallelSampling,
    PipelinePolicy, PolicyBundle, SamplePolicy, SerialTurnBarrier, StaticPlacement, SyncPipeline,
};
use flexmarl::workload::scenario;

fn small_cfg(fw: Framework, scenario: &str) -> ExperimentConfig {
    let mut wl = WorkloadConfig::ma();
    wl.queries_per_step = 2;
    wl.group_size = 4;
    wl.scenario = scenario.to_string();
    let mut cfg = ExperimentConfig::new(wl, fw);
    cfg.steps = 2;
    cfg.seed = 2048; // paper §8.1
    cfg
}

/// Hand-assembled canonical bundle per baseline — deliberately *not*
/// via `Framework::policies()`, so a derivation bug cannot hide on
/// both sides of the comparison.
fn hand_bundle(fw: &Framework) -> PolicyBundle {
    let pipeline: Box<dyn PipelinePolicy> = match fw.name {
        "MARTI" => Box::new(OneStepAsync::default()),
        "FlexMARL" => Box::new(MicroBatchAsync),
        "MAS-RL" | "DistRL" => Box::new(SyncPipeline),
        other => panic!("no hand bundle for {other}"),
    };
    let balance: Box<dyn BalancePolicy> = if fw.name == "FlexMARL" {
        Box::new(HierarchicalBalance)
    } else {
        Box::new(StaticPlacement)
    };
    let alloc: Box<dyn AllocPolicy> = match fw.name {
        "FlexMARL" => Box::new(AgentCentricAlloc),
        "DistRL" => Box::new(DisaggregatedStatic),
        _ => Box::new(ColocatedStatic),
    };
    let sample: Box<dyn SamplePolicy> = if fw.name == "MAS-RL" {
        Box::new(SerialTurnBarrier)
    } else {
        Box::new(ParallelSampling)
    };
    PolicyBundle::new(fw.name, pipeline, balance, alloc, sample)
}

fn report_json(out: &flexmarl::orchestrator::SimOutcome) -> String {
    out.reports
        .iter()
        .map(|r| r.to_json().to_pretty())
        .collect::<Vec<_>>()
        .join("\n")
}

#[test]
fn golden_grid_flag_derived_equals_hand_assembled_bundles() {
    // 4 baselines × 7 presets, fixed paper seed: the engine under a
    // hand-assembled bundle serializes byte-identical StepReport JSON
    // to the flag-derived path every driver uses.
    let opts = SimOptions::default();
    for fw in Framework::all_baselines() {
        for preset in scenario::names() {
            let cfg = small_cfg(fw, preset);
            let derived = try_simulate(&cfg, &opts).unwrap();
            let hand = Experiment::new(cfg)
                .options(opts.clone())
                .policies(hand_bundle(&fw))
                .build()
                .unwrap()
                .run();
            assert_eq!(derived.total_s, hand.total_s, "{} / {preset}", fw.name);
            assert_eq!(
                report_json(&derived),
                report_json(&hand),
                "{} / {preset}: StepReport JSON diverged",
                fw.name
            );
        }
    }
}

#[test]
fn golden_grid_builder_equals_direct_entry() {
    // The Experiment builder (the new single typed entry point) is the
    // same engine as try_simulate, byte for byte.
    let opts = SimOptions {
        track_agents: vec![0, 1],
        ..SimOptions::default()
    };
    for fw in Framework::all_baselines() {
        let cfg = small_cfg(fw, "core_skew");
        let direct = try_simulate(&cfg, &opts).unwrap();
        let built = Experiment::new(cfg).options(opts.clone()).build().unwrap().run();
        assert_eq!(report_json(&direct), report_json(&built), "{}", fw.name);
    }
}

#[test]
fn new_framework_registers_as_policy_bundle_without_engine_edits() {
    // Acceptance criterion: a framework the five capability flags
    // cannot express — colocated pool with *on-demand* binding plus the
    // micro-batch async pipeline and hierarchical balancing — runs
    // end-to-end as a bundle. No simloop edits, no new Framework flags.
    // (Note the documented cross-trait rule: with a colocated pool and
    // no step overlap, phase alternation defers training to the rollout
    // barrier, so the async pipeline's early admission is inert here —
    // the bundle still differs from FlexMARL in pool accounting,
    // binding, and decode contention.)
    let mk = || {
        PolicyBundle::new(
            "HybridRL",
            Box::new(MicroBatchAsync),
            Box::new(HierarchicalBalance),
            Box::new(ColocatedOnDemand),
            Box::new(ParallelSampling),
        )
    };
    let cfg = small_cfg(Framework::flexmarl(), "core_skew");
    let out = Experiment::new(cfg.clone())
        .policies(mk())
        .build()
        .unwrap()
        .run();
    assert_eq!(out.reports.len(), 2);
    assert!(out.total_s > 0.0);
    for r in &out.reports {
        assert_eq!(r.framework, "HybridRL");
        assert!(r.tokens > 0.0);
        assert!(r.e2e_s > 0.0);
    }
    // It genuinely behaves differently from FlexMARL (colocated pool:
    // smaller device pool and decode contention while training).
    let flex = try_simulate(&cfg, &SimOptions::default()).unwrap();
    assert_ne!(
        flex.reports[0].pool_devices, out.reports[0].pool_devices,
        "hybrid colocated pool should provision differently from disaggregated FlexMARL"
    );
    // Deterministic like every other bundle.
    let again = Experiment::new(cfg).policies(mk()).build().unwrap().run();
    assert_eq!(out.total_s, again.total_s);
}

#[test]
fn derived_bundle_report_labels_match_framework_names() {
    // The bundle's name labels reports; for flag-derived bundles it is
    // the framework name — report JSON cannot drift on relabeling.
    for fw in Framework::all_baselines() {
        let cfg = small_cfg(fw, "baseline");
        let out = try_simulate(&cfg, &SimOptions::default()).unwrap();
        for r in &out.reports {
            assert_eq!(r.framework, fw.name);
        }
    }
}
