//! Determinism-under-chaos acceptance tests (ISSUE 6).
//!
//! The contracts pinned here:
//!
//! * **Empty plan = no-fault path** — a config that never mentions
//!   faults, one with an explicit empty `faults` section, and one with
//!   an empty plan but a non-default generator seed all serialize
//!   byte-identical StepReport JSON across the 4-baseline × 7-preset
//!   golden grid, with every recovery-accounting field zero.
//! * **Thread-count invariance under chaos** — a stochastic `FaultPlan`
//!   (random seeds × frameworks × presets) produces byte-identical grid
//!   JSON for `jobs ∈ {1, 2, 8}`, extending the PR 3 contract.
//! * **Streamed = monolithic under faults** — driving a `Session` to
//!   exhaustion under a fault preset matches `Experiment::run()` byte
//!   for byte.
//! * **Recovery policies diverge visibly** — fail-fast, retry-with-
//!   backoff and degrade-and-rebalance produce distinguishable recovery
//!   accounting on the same preemption plan, with fail-fast surfacing
//!   the typed `PallasError::InstanceLost`.

use flexmarl::config::{ExperimentConfig, Framework, WorkloadConfig};
use flexmarl::error::PallasError;
use flexmarl::exec::{grid_report, run_specs_or_panic, Overrides, RunGrid};
use flexmarl::experiment::Experiment;
use flexmarl::fault::{preset, FaultConfig};
use flexmarl::metrics::StepReport;
use flexmarl::orchestrator::{try_simulate, SimOptions};
use flexmarl::workload::scenario;

fn small_cfg(fw: Framework, preset: &str) -> ExperimentConfig {
    let mut wl = WorkloadConfig::ma();
    wl.queries_per_step = 2;
    wl.group_size = 4;
    wl.scenario = preset.to_string();
    let mut cfg = ExperimentConfig::new(wl, fw);
    cfg.steps = 2;
    cfg.seed = 2048; // paper §8.1
    cfg
}

fn report_json(reports: &[StepReport]) -> String {
    reports
        .iter()
        .map(|r| r.to_json().to_pretty())
        .collect::<Vec<_>>()
        .join("\n")
}

fn drain_session(cfg: &ExperimentConfig, opts: &SimOptions) -> flexmarl::orchestrator::SimOutcome {
    let mut session = Experiment::new(cfg.clone())
        .options(opts.clone())
        .build()
        .unwrap()
        .session()
        .unwrap();
    while session.step().unwrap().is_some() {}
    session.finish()
}

// ---------------------------------------------------------------------------
// Empty plan == no-fault path (golden grid)
// ---------------------------------------------------------------------------

#[test]
fn empty_plan_is_byte_identical_to_no_fault_path_on_golden_grid() {
    // 4 baselines × 7 presets at the paper seed. Three spellings of
    // "no faults" must be bit-equal: the default config, an empty
    // FaultConfig carrying a generator seed (plan resolution must not
    // consume entropy or inject anything when every source is empty),
    // and an empty plan with a recovery override (the policy is inert
    // when no fault ever fires).
    let opts = SimOptions::default();
    for fw in Framework::all_baselines() {
        for name in scenario::names() {
            let base = small_cfg(fw, name);
            let absent = try_simulate(&base, &opts).unwrap();
            for r in &absent.reports {
                assert_eq!(r.retries, 0, "{} / {name}", fw.name);
                assert_eq!(r.lost_tokens, 0.0, "{} / {name}", fw.name);
                assert_eq!(r.recovery_s, 0.0, "{} / {name}", fw.name);
                assert_eq!(r.degraded_s, 0.0, "{} / {name}", fw.name);
            }
            let mut seeded = base.clone();
            seeded.faults = FaultConfig {
                seed: Some(7),
                ..FaultConfig::default()
            };
            assert!(seeded.faults.is_empty());
            let mut overridden = base.clone();
            overridden.faults = FaultConfig {
                recovery: Some("retry".into()),
                ..FaultConfig::default()
            };
            for variant in [&seeded, &overridden] {
                let out = try_simulate(variant, &opts).unwrap();
                assert_eq!(out.total_s, absent.total_s, "{} / {name}", fw.name);
                assert_eq!(
                    report_json(&out.reports),
                    report_json(&absent.reports),
                    "{} / {name}: empty fault plan perturbed the run",
                    fw.name
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Thread-count invariance under chaos
// ---------------------------------------------------------------------------

#[test]
fn random_fault_plans_are_byte_identical_across_jobs() {
    // Stochastic plans from three generator seeds, swept over a
    // frameworks × scenarios grid (framework defaults pick different
    // recovery policies: FlexMARL degrades, the others retry) — the
    // grid JSON must not depend on --jobs.
    let opts = SimOptions::default();
    for fault_seed in [7u64, 99, 424242] {
        let mut base = small_cfg(Framework::flexmarl(), "baseline");
        base.faults = FaultConfig {
            crashes: 1,
            preemptions: 1,
            stragglers: 2,
            flaps: 1,
            resizes: 1,
            horizon_s: 120.0,
            seed: Some(fault_seed),
            ..FaultConfig::default()
        };
        base.validate().unwrap();
        let grid = RunGrid {
            frameworks: vec![Framework::flexmarl(), Framework::dist_rl(), Framework::marti()],
            scenarios: vec!["baseline".into(), "core_skew".into()],
            replicates: 1,
            overrides: Overrides::default(),
        };
        let specs = grid.specs(&base);
        let render = |jobs: usize| {
            let reports = run_specs_or_panic(&base, &opts, &specs, jobs);
            grid_report(&base, &specs, &reports).to_pretty()
        };
        let one = render(1);
        assert_eq!(one, render(2), "fault_seed={fault_seed} jobs=2 diverged");
        assert_eq!(one, render(8), "fault_seed={fault_seed} jobs=8 diverged");
        // The plan genuinely did something: at least one cell accounts
        // for recovery (a silent no-op plan would vacuously pass).
        let reports = run_specs_or_panic(&base, &opts, &specs, 1);
        assert!(
            reports
                .iter()
                .any(|r| r.retries > 0 || r.lost_tokens > 0.0 || r.degraded_s > 0.0),
            "fault_seed={fault_seed}: no cell shows any recovery accounting"
        );
    }
}

// ---------------------------------------------------------------------------
// Streamed == monolithic under faults
// ---------------------------------------------------------------------------

#[test]
fn faulted_session_stream_matches_monolithic_run() {
    let opts = SimOptions {
        track_agents: vec![0, 1],
        ..SimOptions::default()
    };
    for name in ["preemption_retry", "preemption_degrade", "flaky", "chaos"] {
        let mut cfg = small_cfg(Framework::flexmarl(), "core_skew");
        cfg.faults = preset(name).unwrap();
        let batch = Experiment::new(cfg.clone())
            .options(opts.clone())
            .build()
            .unwrap()
            .run();
        let streamed = drain_session(&cfg, &opts);
        assert_eq!(batch.total_s, streamed.total_s, "{name}");
        assert_eq!(
            report_json(&batch.reports),
            report_json(&streamed.reports),
            "{name}: streamed reports diverged from monolithic"
        );
        assert_eq!(batch.series, streamed.series, "{name}: run series diverged");
    }
}

// ---------------------------------------------------------------------------
// Recovery policies diverge visibly (acceptance criterion)
// ---------------------------------------------------------------------------

#[test]
fn recovery_policies_diverge_visibly_on_the_preemption_plan() {
    let opts = SimOptions::default();
    let run = |preset_name: &str| -> Vec<StepReport> {
        let mut cfg = small_cfg(Framework::flexmarl(), "core_skew");
        cfg.faults = preset(preset_name).unwrap();
        try_simulate(&cfg, &opts).unwrap().reports
    };

    // Retry-with-backoff: displaced requests wait out the backoff and
    // re-dispatch — retries and recovery time accrue, no degraded
    // window is ever charged.
    let retry: StepReport = flexmarl::metrics::aggregate(&run("preemption_retry"));
    let retries_total: usize = run("preemption_retry").iter().map(|r| r.retries).sum();
    assert!(retries_total > 0, "retry policy never re-dispatched");
    assert!(retry.recovery_s > 0.0, "retry policy charged no backoff");
    assert_eq!(retry.degraded_s, 0.0, "retry policy must not degrade");

    // Degrade-and-rebalance: survivors absorb the work immediately
    // (no retries, no backoff) and a degraded-capacity window is
    // charged until the replacement comes up.
    let degrade_reports = run("preemption_degrade");
    let degrade = flexmarl::metrics::aggregate(&degrade_reports);
    assert!(degrade.degraded_s > 0.0, "degrade policy charged no window");
    let degrade_retries: usize = degrade_reports.iter().map(|r| r.retries).sum();
    assert_eq!(degrade_retries, 0, "degrade policy must not retry");
    assert_eq!(degrade.recovery_s, 0.0, "degrade policy has no backoff");

    // Both lose the mid-decode work of the killed instances.
    let lost: f64 = run("preemption_retry").iter().map(|r| r.lost_tokens).sum::<f64>()
        + degrade_reports.iter().map(|r| r.lost_tokens).sum::<f64>();
    assert!(lost > 0.0, "no policy accounted any lost work");

    // The two recovering policies are visibly different end to end.
    assert_ne!(
        report_json(&run("preemption_retry")),
        report_json(&degrade_reports),
        "retry and degrade produced identical reports"
    );

    // Fail-fast: the same plan aborts with the typed error instead.
    let mut cfg = small_cfg(Framework::flexmarl(), "core_skew");
    cfg.faults = preset("preemption_failfast").unwrap();
    let err = Experiment::new(cfg)
        .options(opts.clone())
        .build()
        .unwrap()
        .try_run()
        .unwrap_err();
    assert!(
        matches!(err, PallasError::InstanceLost { .. }),
        "expected InstanceLost, got {err:?}"
    );
    assert!(err.to_string().contains("fail-fast"), "{err}");
}

// ---------------------------------------------------------------------------
// Determinism of a single faulted run (same seed, same bytes)
// ---------------------------------------------------------------------------

#[test]
fn same_seed_same_plan_same_bytes() {
    let opts = SimOptions::default();
    for name in ["preemption_retry", "flaky", "chaos"] {
        let mut cfg = small_cfg(Framework::flexmarl(), "baseline");
        cfg.faults = preset(name).unwrap();
        let a = try_simulate(&cfg, &opts).unwrap();
        let b = try_simulate(&cfg, &opts).unwrap();
        assert_eq!(a.total_s, b.total_s, "{name}");
        assert_eq!(report_json(&a.reports), report_json(&b.reports), "{name}");
        // And a different experiment seed genuinely moves the run.
        let mut other = cfg.clone();
        other.seed = 7;
        let c = try_simulate(&other, &opts).unwrap();
        assert_ne!(
            report_json(&a.reports),
            report_json(&c.reports),
            "{name}: seed change had no effect"
        );
    }
}
