//! Rollout-engine serving demo under *wall clock*: a vLLM-router-style
//! deployment of the FlexMARL rollout engine with real threads.
//!
//! N worker threads play inference instances (their per-request latency
//! follows the MA workload's long-tail token distribution, time-scaled
//! 200×); the main thread is the rollout manager: min-heap least-loaded
//! dispatch, queue-length polling, and inter-agent scaling through the
//! Set/Get store when the Δ-threshold trips. Demonstrates that the
//! scheduling components are runtime-agnostic — the same code the
//! virtual-time simulator drives (deliverable (b), domain scenario 2).
//!
//! Run: `cargo run --release --example rollout_serve -- --queries 24`
//! Traffic shapes: `--scenario <preset>` (see `flexmarl scenarios`);
//! `--trace <path>` replays a recorded JSONL trace instead.

use flexmarl::config::{ExperimentConfig, Framework, WorkloadConfig};
use flexmarl::experiment::Experiment;
use flexmarl::memstore::{Location, MemStore, TransferModel};
use flexmarl::rollout::{plan_migration, Dispatch, RolloutManager};
use flexmarl::util::cli::Args;
use std::collections::BTreeMap;
use std::sync::mpsc;
use std::time::{Duration, Instant};

const TIME_SCALE: f64 = 200.0; // simulated seconds per wall second

fn main() {
    let args = Args::from_env();
    let mut wl = WorkloadConfig::ma();
    wl.queries_per_step = args.get_usize("queries", 24) / wl.group_size.clamp(1, 16);
    wl.queries_per_step = wl.queries_per_step.max(2);
    wl.group_size = 4;
    wl.scenario = args.get_or("scenario", "baseline");
    let delta = args.get_usize("delta", 5);

    // Exactly the simulator's source-selection path, through the typed
    // Experiment builder: scenario-shaped generation, or bit-identical
    // replay of a recorded trace (header authoritative, n_agents
    // validated) — no parallel logic to drift.
    if let Some(path) = args.get("trace") {
        wl.trace = Some(path.to_string());
    }
    let mut cfg = ExperimentConfig::new(wl, Framework::flexmarl());
    cfg.seed = args.get_u64("seed", 2048); // steps stays 1: serve step 0
    let exp = Experiment::new(cfg).build().unwrap_or_else(|e| {
        eprintln!("workload resolution failed: {e}");
        std::process::exit(1)
    });
    let (resolved, mut step_wls) = exp.into_workloads();
    if step_wls.is_empty() {
        eprintln!("trace has no steps");
        std::process::exit(1)
    }
    if step_wls.len() > 1 {
        eprintln!(
            "note: trace has {} steps; this wall-clock demo serves step 0 only",
            step_wls.len()
        );
    }
    let wl = resolved.workload;
    let workload = step_wls.remove(0);
    let n_agents = wl.agents.len();
    println!(
        "serving {} trajectories ({} calls) across {} agents, scenario '{}' (Δ = {delta}, time×{TIME_SCALE})",
        workload.trajectories.len(),
        workload.total_calls(),
        n_agents,
        wl.scenario,
    );

    let store = MemStore::new();
    let transfer = TransferModel::new(Default::default());
    let mut man = RolloutManager::new(n_agents);
    for a in 0..n_agents {
        man.add_instance(a, 4);
        man.add_instance(a, 4);
        // Publish each agent's weights once (§7 Set).
        store.set(
            &format!("agent/{a}/weights"),
            Location::Device(a * 4),
            wl.agents[a].model.weight_bytes(),
            None,
        );
    }

    // Flatten calls into (request, agent, service_ms); chains dispatch
    // sequentially per trajectory (dependency-driven).
    let (done_tx, done_rx) = mpsc::channel::<u64>();
    let mut next_call: Vec<usize> = vec![0; workload.trajectories.len()];
    let mut req_meta: BTreeMap<u64, (usize, usize, u64)> = BTreeMap::new(); // rid -> (traj, agent, service_ms)
    let mut next_rid = 0u64;
    let mut completed_calls = 0usize;
    let total_calls = workload.total_calls();
    let mut scale_ops = 0usize;
    let t0 = Instant::now();

    let spawn_service = |rid: u64, ms: u64, tx: mpsc::Sender<u64>| {
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(ms));
            let _ = tx.send(rid);
        });
    };

    let submit = |man: &mut RolloutManager,
                  req_meta: &mut BTreeMap<u64, (usize, usize, u64)>,
                  next_rid: &mut u64,
                  traj: usize,
                  call: usize| {
        let spec = &workload.trajectories[traj].calls[call];
        let rid = *next_rid;
        *next_rid += 1;
        let ms = ((spec.tokens / wl.agents[spec.agent].model.decode_tps() + spec.env_s)
            / TIME_SCALE
            * 1000.0) as u64;
        req_meta.insert(rid, (traj, spec.agent, ms));
        if let Dispatch::Started(_) = man.submit(rid, spec.agent) {
            spawn_service(rid, ms.max(1), done_tx.clone());
        }
        // Queued requests start when the manager promotes them (below).
    };

    // Kick off call 0 of every trajectory.
    for traj in 0..workload.trajectories.len() {
        submit(&mut man, &mut req_meta, &mut next_rid, traj, 0);
    }

    let mut last_poll = Instant::now();
    while completed_calls < total_calls {
        if let Ok(rid) = done_rx.recv_timeout(Duration::from_millis(20)) {
            let (traj, _agent, _) = req_meta[&rid];
            if let Some(promoted) = man.complete(rid) {
                let (_, _, pms) = req_meta[&promoted];
                spawn_service(promoted, pms.max(1), done_tx.clone());
            }
            completed_calls += 1;
            next_call[traj] += 1;
            if next_call[traj] < workload.trajectories[traj].calls.len() {
                let c = next_call[traj];
                submit(&mut man, &mut req_meta, &mut next_rid, traj, c);
            }
        }
        // Poll + inter-agent balancing (§5.2) every scaled 2 s.
        if last_poll.elapsed() > Duration::from_millis((2000.0 / TIME_SCALE) as u64 * 10) {
            last_poll = Instant::now();
            let q = man.queue_lens();
            let counts = man.instance_counts();
            if let Some(plan) = plan_migration(&q, &counts, delta, &vec![false; n_agents]) {
                let insts = man.instances_of(plan.donor);
                let mut moved = 0;
                for iid in insts.into_iter().take(plan.n_instances) {
                    let displaced = man.drain_instance(iid);
                    if man.is_drained(iid) {
                        man.remove_instance(iid);
                        let (_, started) = man.add_instance(plan.target, 4);
                        for rid in started {
                            let (_, _, ms) = req_meta[&rid];
                            spawn_service(rid, ms.max(1), done_tx.clone());
                        }
                        for rid in displaced {
                            let (_, agent, ms) = req_meta[&rid];
                            if let Dispatch::Started(_) = man.submit(rid, agent) {
                                spawn_service(rid, ms.max(1), done_tx.clone());
                            }
                        }
                        moved += 1;
                    }
                }
                if moved > 0 {
                    // Weight migration via Get (D2D, contiguous buffer).
                    let plan_t = store
                        .get(
                            &format!("agent/{}/weights", plan.target),
                            Location::Device(plan.donor * 4),
                            &transfer,
                        )
                        .unwrap();
                    scale_ops += 1;
                    println!(
                        "  [scale] agent {} → {} ({} inst, disparity {}, weights {:.0} MiB in {:.0} ms)",
                        plan.donor,
                        plan.target,
                        moved,
                        plan.disparity,
                        plan_t.bytes / (1 << 20) as f64,
                        plan_t.seconds * 1000.0
                    );
                }
            }
        }
    }

    let wall = t0.elapsed().as_secs_f64();
    println!(
        "\nserved {total_calls} calls in {wall:.1}s wall ({:.0}s simulated)",
        wall * TIME_SCALE
    );
    println!("scaling operations: {scale_ops}");
    for a in 0..n_agents {
        println!(
            "  {:<22} processed {:>4}  instances now {}",
            wl.agents[a].name,
            man.completed_per_agent[a],
            man.instance_count(a)
        );
    }
}
