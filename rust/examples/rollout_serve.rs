//! Rollout-as-a-Service demo on the serving plane (DESIGN.md §13).
//!
//! Builds a named tenant mix, runs it twice through
//! [`flexmarl::serve::ServePlane`] — once on a single worker, once on
//! `--workers` threads — and verifies the plane's determinism contract
//! live: every per-session JSONL stream and the whole load report are
//! byte-identical across the two runs, while wall time shows the
//! worker-pool speedup. The same `ServePlane` backs the `flexmarl
//! serve` subcommand; this example is the library-API view of it.
//!
//! Run: `cargo run --release --example rollout_serve -- --mix flash`
//! Knobs: `--mix steady|mixed|flash  --ticks N  --seed N  --workers N`

use flexmarl::serve::{ServeConfig, ServeOutcome, ServePlane};
use flexmarl::util::cli::Args;
use flexmarl::util::pool;

fn run(cfg: &ServeConfig, workers: usize) -> ServeOutcome {
    let plane = ServePlane::new(cfg.clone(), workers).unwrap_or_else(|e| {
        eprintln!("invalid serve config: {e}");
        std::process::exit(2)
    });
    plane.run().unwrap_or_else(|e| {
        eprintln!("serve failed: {e}");
        std::process::exit(1)
    })
}

fn main() {
    let args = Args::from_env();
    let mix = args.get_or("mix", "mixed");
    let seed = args.get_u64("seed", 2048);
    let workers = args.get_usize("workers", pool::default_jobs().max(2));
    let mut cfg = ServeConfig::mix(&mix, seed).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2)
    });
    cfg.ticks = args.get_u64("ticks", 80);

    println!(
        "serving mix '{mix}' (seed {seed}): {} tenants, {} ticks, {} slots, queue cap {}",
        cfg.tenants.len(),
        cfg.ticks,
        cfg.slots,
        cfg.queue_cap
    );

    let solo = run(&cfg, 1);
    let multi = run(&cfg, workers);

    // The determinism contract, checked live: scheduling happened in
    // virtual time before execution, so nothing — not one byte —
    // depends on the worker count.
    assert_eq!(
        solo.report.to_json().to_pretty(),
        multi.report.to_json().to_pretty(),
        "load report depends on worker count"
    );
    assert_eq!(solo.sessions.len(), multi.sessions.len());
    for (a, b) in solo.sessions.iter().zip(&multi.sessions) {
        assert_eq!(a.seq, b.seq);
        assert_eq!(a.jsonl, b.jsonl, "session {} bytes depend on worker count", a.seq);
    }

    let r = &multi.report;
    println!(
        "\n{} submitted | {} admitted | {} rejected (queue_full {}, quota {}) | {} expired | {} completed",
        r.submitted,
        r.admitted,
        r.rejected_queue_full + r.rejected_quota,
        r.rejected_queue_full,
        r.rejected_quota,
        r.expired,
        r.completed
    );
    println!(
        "makespan {} ticks  {:.2} sessions/kilotick  queue depth max {} mean {:.2}",
        r.makespan_ticks, r.sessions_per_kilotick, r.queue_depth_max, r.queue_depth_mean
    );
    println!(
        "wait p50 {:.0} p90 {:.0} p99 {:.0} ticks  step latency p50 {:.1}s p99 {:.1}s (virtual)",
        r.wait_ticks.p50(),
        r.wait_ticks.p90(),
        r.wait_ticks.p99(),
        r.step_latency_s.p50(),
        r.step_latency_s.p99()
    );
    println!("\n{:<14} {:>9} {:>9} {:>9} {:>8} {:>10}", "tenant", "submitted", "completed", "rejected", "expired", "wait p99");
    for t in &r.tenants {
        println!(
            "{:<14} {:>9} {:>9} {:>9} {:>8} {:>10.0}",
            t.name,
            t.submitted,
            t.completed,
            t.rejected_queue_full + t.rejected_quota,
            t.expired,
            t.wait_ticks.p99()
        );
    }
    println!(
        "\nbyte-identical across worker counts ✓   wall: {:.2}s @1 worker vs {:.2}s @{} workers ({:.1}x)",
        solo.wall_s,
        multi.wall_s,
        workers,
        solo.wall_s / multi.wall_s.max(1e-9)
    );
}
