//! Quickstart: the typed `Experiment` API plus the three-layer stack.
//!
//! Part 1 needs nothing but the crate: it runs a paper-scale experiment
//! on the cluster simulator through the [`Experiment`] builder — the
//! single entry point the CLI, baselines, sweeps, and benches all use —
//! then re-runs it through the streaming `Session` API (step-at-a-time
//! reports, typed event sinks, early stop; DESIGN.md §9).
//!
//! Part 2 (skipped gracefully when `artifacts/` is absent) exercises
//! the real runtime: loads the AOT artifacts (L2 JAX model + L1 Pallas
//! kernels, compiled to HLO by `make artifacts`), spins up one agent
//! policy on the PJRT CPU client, generates a GRPO candidate group,
//! scores it, and performs one micro-batch gradient step + update.
//!
//! Run: `cargo run --release --example quickstart`
//! (add `make artifacts` first to unlock Part 2)

use flexmarl::config::{ExperimentConfig, Framework, WorkloadConfig};
use flexmarl::experiment::Experiment;
use flexmarl::grpo::{group_advantages, make_row};
use flexmarl::orchestrator::{BudgetSink, ProgressSink};
use flexmarl::runtime::policy::AgentPolicy;
use flexmarl::runtime::ModelRuntime;
use flexmarl::util::rng::Pcg64;
use flexmarl::workload::corpus::CorpusConfig;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // ---- Part 1: simulator via the Experiment builder -------------------
    println!("== Part 1: paper-scale simulation (Experiment builder) ==");
    let cfg = ExperimentConfig::new(WorkloadConfig::ma(), Framework::flexmarl());
    let report = Experiment::new(cfg)
        .scenario("core_skew") // Obs. 2 sharpened: LB must migrate
        .steps(2)
        .build()? // typed error on bad scenario/trace — no panics
        .evaluate();
    println!(
        "FlexMARL on MA/core_skew: e2e {:.1}s  rollout {:.1}s  train {:.1}s  \
         {:.0} tok/s  util {:.1}%  scale_ops {}",
        report.e2e_s,
        report.rollout_s,
        report.train_s,
        report.throughput_tps(),
        report.utilization() * 100.0,
        report.scale_ops
    );

    // ---- Part 1b: the same experiment, streamed ------------------------
    // A Session steps the engine one MARL step at a time; each yielded
    // report is bit-identical to the batch run's. A budget sink shows
    // early stop: the run halts mid-flight with a well-formed partial
    // outcome.
    println!("\n== Part 1b: streaming Session (step-at-a-time, early stop) ==");
    let cfg = ExperimentConfig::new(WorkloadConfig::ma(), Framework::flexmarl());
    let mut session = Experiment::new(cfg)
        .scenario("core_skew")
        .steps(3)
        .build()?
        .session()?;
    session.add_sink(Box::new(BudgetSink::new().max_steps(2)));
    while let Some(step) = session.step()? {
        println!(
            "  step done at t={:.1}s: e2e {:.1}s  {:.0} tok/s",
            session.now(),
            step.e2e_s,
            step.throughput_tps()
        );
    }
    let outcome = session.finish();
    println!(
        "  stopped early: {} (completed {}/3 steps, t={:.1}s)",
        outcome.stop.is_some(),
        outcome.reports.len(),
        outcome.total_s
    );

    // ---- Part 1c: chaos — faults, recovery, live progress ---------------
    // The fault plane (DESIGN.md §10) injects failures as ordinary timed
    // simulator events: the run stays fully deterministic, and the
    // bundle's RecoveryPolicy (here retry-with-backoff, via the preset's
    // override) re-dispatches the displaced work. A ProgressSink narrates
    // the strikes and recoveries on stderr.
    println!("\n== Part 1c: fault injection + recovery (chaos) ==");
    let mut cfg = ExperimentConfig::new(WorkloadConfig::ma(), Framework::flexmarl());
    cfg.faults = flexmarl::fault::preset("preemption_retry").expect("shipped preset");
    let mut session = Experiment::new(cfg)
        .scenario("core_skew")
        .steps(2)
        .build()?
        .session()?;
    session.add_sink(Box::new(ProgressSink::stderr(2)));
    while let Some(step) = session.step()? {
        println!(
            "  step done: e2e {:.1}s  retries {}  lost {:.0} tok  \
             recovery {:.1}s  degraded {:.1}s",
            step.e2e_s, step.retries, step.lost_tokens, step.recovery_s, step.degraded_s
        );
    }
    let outcome = session.finish();
    println!("  faulted run completed {} steps, t={:.1}s", outcome.reports.len(), outcome.total_s);

    // ---- Part 1d: kill-safe runs — checkpoint & byte-identical resume ----
    // A Session snapshots its complete mutable state (DESIGN.md §12):
    // save mid-run, "crash", rebuild the experiment, resume from the
    // file — the resumed run finishes with byte-identical metrics.
    println!("\n== Part 1d: checkpoint / resume (crash-consistent, byte-identical) ==");
    let ckpt_path = std::env::temp_dir()
        .join(format!("flexmarl_quickstart_{}.ckpt", std::process::id()))
        .to_str()
        .expect("temp path is utf-8")
        .to_string();
    let build = || {
        let cfg = ExperimentConfig::new(WorkloadConfig::ma(), Framework::flexmarl());
        Experiment::new(cfg).scenario("poisson").steps(3).build()
    };
    let mut session = build()?.session()?;
    session.step()?.expect("step 0"); // run one step...
    session.save(&ckpt_path)?; // ...checkpoint (temp file + atomic rename)...
    drop(session); // ...and "crash".
    let mut resumed = build()?.resume_file(&ckpt_path)?; // typed errors on corrupt/stale files
    println!("  resumed at step {} from {ckpt_path}", resumed.steps_completed());
    while let Some(step) = resumed.step()? {
        println!("  step done: e2e {:.1}s  {:.0} tok/s", step.e2e_s, step.throughput_tps());
    }
    let outcome = resumed.finish();
    println!(
        "  resumed run completed {}/3 steps, t={:.1}s (byte-identical to uninterrupted)",
        outcome.reports.len(),
        outcome.total_s
    );
    let _ = std::fs::remove_file(&ckpt_path);

    // ---- Part 2: real PJRT runtime (optional) ---------------------------
    // Only the *default* location skips silently; an explicitly passed
    // dir that does not resolve must fail loudly below (a typo'd path
    // reading as success would be worse than the old behaviour).
    let explicit = std::env::args().nth(1);
    let dir = explicit.clone().unwrap_or_else(|| "artifacts".into());
    if explicit.is_none() && !std::path::Path::new(&dir).join("manifest.json").exists() {
        println!("\n== Part 2 skipped: no {dir}/manifest.json (run `make artifacts`) ==");
        println!("\nquickstart OK");
        return Ok(());
    }
    println!("\n== Part 2: PJRT end-to-end ==");
    println!("loading artifacts from {dir}/ ...");
    let rt = ModelRuntime::load(&dir)?;
    println!("{}", rt.manifest.summary());

    let sh = rt.manifest.shapes.clone();
    let corpus = CorpusConfig::new(rt.manifest.model.vocab, sh.t_prompt);
    let mut policy = AgentPolicy::new(&rt, 0, 2048)?;
    let mut rng = Pcg64::new(7);

    // One user query → a GRPO candidate group (intra-query parallelism).
    let topic = 3;
    let prompt = corpus.make_prompt(&mut rng, topic);
    let prompts: Vec<Vec<i32>> = (0..sh.b_roll).map(|_| prompt.clone()).collect();
    println!("\ngenerating {} candidates × 24 tokens ...", sh.b_roll);
    let rollouts = policy.generate(&rt, &prompts, 24, 1.0)?;

    let rewards: Vec<f64> = rollouts
        .iter()
        .map(|r| corpus.reward(0, topic, &r.response))
        .collect();
    let advs = group_advantages(&rewards);
    for (i, (r, a)) in rewards.iter().zip(&advs).enumerate() {
        println!("  candidate {i}: reward {r:.3}  advantage {a:+.3}");
    }

    // One micro batch: gradient computation is decoupled from the update
    // (§4.3) — grads go to the agent's cache, then one unified apply.
    let rows: Vec<_> = rollouts
        .iter()
        .zip(&advs)
        .map(|(r, &a)| make_row(&prompt, &r.response, &r.logp, a as f32, sh.t_train))
        .collect();
    let stats = policy.grad_on_rows(&rt, &rows)?;
    println!(
        "\ngrad micro-batch: loss {:+.4}  kl {:.5}  ratio {:.3}  entropy {:.2}  |g| {:.3}",
        stats.loss, stats.kl, stats.ratio, stats.entropy, stats.grad_norm
    );
    policy.apply(&rt, 3e-4)?;
    println!("applied update → policy_version = {}", policy.version);
    println!("\nquickstart OK");
    Ok(())
}
