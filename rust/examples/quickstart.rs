//! Quickstart: the three-layer stack in ~60 lines.
//!
//! Loads the AOT artifacts (L2 JAX model + L1 Pallas kernels, compiled
//! to HLO by `make artifacts`), spins up one agent policy on the PJRT
//! CPU client, generates a GRPO candidate group for a synthetic query,
//! scores it with the rule-based reward, and performs one micro-batch
//! gradient step + parameter update through the experience-store
//! pipeline primitives.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use flexmarl::grpo::{group_advantages, make_row};
use flexmarl::runtime::policy::AgentPolicy;
use flexmarl::runtime::ModelRuntime;
use flexmarl::util::rng::Pcg64;
use flexmarl::workload::corpus::CorpusConfig;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    println!("loading artifacts from {dir}/ ...");
    let rt = ModelRuntime::load(&dir)?;
    println!("{}", rt.manifest.summary());

    let sh = rt.manifest.shapes.clone();
    let corpus = CorpusConfig::new(rt.manifest.model.vocab, sh.t_prompt);
    let mut policy = AgentPolicy::new(&rt, 0, 2048)?;
    let mut rng = Pcg64::new(7);

    // One user query → a GRPO candidate group (intra-query parallelism).
    let topic = 3;
    let prompt = corpus.make_prompt(&mut rng, topic);
    let prompts: Vec<Vec<i32>> = (0..sh.b_roll).map(|_| prompt.clone()).collect();
    println!("\ngenerating {} candidates × 24 tokens ...", sh.b_roll);
    let rollouts = policy.generate(&rt, &prompts, 24, 1.0)?;

    let rewards: Vec<f64> = rollouts
        .iter()
        .map(|r| corpus.reward(0, topic, &r.response))
        .collect();
    let advs = group_advantages(&rewards);
    for (i, (r, a)) in rewards.iter().zip(&advs).enumerate() {
        println!("  candidate {i}: reward {r:.3}  advantage {a:+.3}");
    }

    // One micro batch: gradient computation is decoupled from the update
    // (§4.3) — grads go to the agent's cache, then one unified apply.
    let rows: Vec<_> = rollouts
        .iter()
        .zip(&advs)
        .map(|(r, &a)| make_row(&prompt, &r.response, &r.logp, a as f32, sh.t_train))
        .collect();
    let stats = policy.grad_on_rows(&rt, &rows)?;
    println!(
        "\ngrad micro-batch: loss {:+.4}  kl {:.5}  ratio {:.3}  entropy {:.2}  |g| {:.3}",
        stats.loss, stats.kl, stats.ratio, stats.entropy, stats.grad_norm
    );
    policy.apply(&rt, 3e-4)?;
    println!("applied update → policy_version = {}", policy.version);
    println!("\nquickstart OK");
    Ok(())
}
