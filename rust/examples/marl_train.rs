//! End-to-end MARL training — the full-system validation driver
//! (deliverable (b) + the EXPERIMENTS.md §E2E run).
//!
//! Trains `--agents N` independent transformer policies with GRPO on the
//! synthetic multi-agent assistant corpus: real autoregressive rollout
//! through the PJRT executables (L1 Pallas attention inside), group
//! advantages, the experience store as the rollout→training data plane,
//! micro-batch gradient accumulation and unified parameter updates.
//! Prints the per-step reward/loss curve and writes
//! `artifacts/e2e_metrics.json`.
//!
//! Run: `cargo run --release --example marl_train -- --steps 60 --agents 3`
//! `--scenario <preset>` derives the query-count/chain-length defaults
//! from the preset's shaped config where the preset shapes those
//! fields (tool_heavy lengthens chains; others keep the baseline
//! workflow shape — token/latency shaping applies to the simulator
//! and serving surfaces, not this tiny-model loop). Explicit
//! `--queries`/`--chain` still win.

use flexmarl::config::WorkloadConfig;
use flexmarl::runtime::marl::{run_loop, E2eOptions};
use flexmarl::util::cli::Args;
use flexmarl::workload::scenario;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::from_env();
    let dir = args.get_or("artifacts", "artifacts");
    let agents = args.get_usize("agents", 3);
    let steps = args.get_usize("steps", 40);
    let seed = args.get_u64("seed", 2048);
    let lr = args.get_f64("lr", 3e-4) as f32;
    let scen_name = args.get_or("scenario", "baseline");
    let mut base = WorkloadConfig::ma();
    base.scenario = scen_name.clone();
    let (shaped, scen) = scenario::resolve(&base).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2)
    });
    // Tiny-model defaults; a non-baseline scenario re-derives them from
    // its shaped config (clamped — the 3M-param policies can't absorb
    // paper-scale chains). Compare the canonical name so aliases like
    // "Base-Line" behave identically.
    let (q_default, chain_default) = if scen.name() == "baseline" {
        (2, 2)
    } else {
        (
            shaped.queries_per_step.clamp(1, 4),
            shaped.min_turns.clamp(1, 4),
        )
    };
    let opts = E2eOptions {
        n_queries: args.get_usize("queries", q_default),
        chain_len: args.get_usize("chain", chain_default),
        gen_len: args.get_usize("gen-len", 32),
        temperature: args.get_f64("temperature", 1.0) as f32,
        easy_task: args.has_flag("easy"),
    };

    println!(
        "MARL e2e: {agents} agents × {steps} steps  (scenario {scen_name}, queries {}, chain {}, gen {})",
        opts.n_queries, opts.chain_len, opts.gen_len
    );
    let logs = run_loop(&dir, agents, steps, seed, lr, &opts, true)?;

    // Persist the curves next to the artifacts (EXPERIMENTS.md §E2E).
    let j = flexmarl::util::json::Json::arr(logs.iter().map(|l| {
        flexmarl::util::json::Json::obj(vec![
            ("step", flexmarl::util::json::Json::num(l.step as f64)),
            ("mean_reward", flexmarl::util::json::Json::num(l.mean_reward)),
            ("mean_loss", flexmarl::util::json::Json::num(l.mean_loss)),
            ("rollout_s", flexmarl::util::json::Json::num(l.rollout_s)),
            ("train_s", flexmarl::util::json::Json::num(l.train_s)),
        ])
    }));
    let _ = std::fs::write(format!("{dir}/e2e_metrics.json"), j.to_pretty());

    // Summary: reward trend over the run (first vs last quartile).
    let q = (logs.len() / 4).max(1);
    let head: f64 = logs[..q].iter().map(|l| l.mean_reward).sum::<f64>() / q as f64;
    let tail: f64 = logs[logs.len() - q..].iter().map(|l| l.mean_reward).sum::<f64>() / q as f64;
    println!("\nmean reward: first {q} steps {head:.3} → last {q} steps {tail:.3}");
    if tail > head {
        println!("✓ policies improved (GRPO learning signal confirmed)");
    } else {
        println!("⚠ no improvement — try more steps (--steps 60) or higher --lr");
    }
    let r: f64 = logs.iter().map(|l| l.rollout_s).sum();
    let t: f64 = logs.iter().map(|l| l.train_s).sum();
    println!("phase split: rollout {r:.1}s, training {t:.1}s");
    Ok(())
}
