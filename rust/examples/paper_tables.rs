//! Regenerate every table and figure of the paper's evaluation (§8) in
//! one run — the reviewer's one-stop driver. Each section prints the
//! paper's reported values next to ours so the *shape* comparison
//! (ordering, rough factors, crossovers) is immediate.
//!
//! Run: `cargo run --release --example paper_tables`

use flexmarl::baselines::{sweep, Framework};
use flexmarl::config::{ClusterConfig, ExperimentConfig, ModelScale, WorkloadConfig};
use flexmarl::experiment::Experiment;
use flexmarl::metrics::{table_rows, StepReport};
use flexmarl::orchestrator::{SimOptions, SimOutcome};
use flexmarl::training::{swap_in_cost, swap_out_cost};

const STEPS: usize = 3;

fn opts() -> SimOptions {
    SimOptions {
        track_agents: vec![0, 1, 2],
        ..SimOptions::default()
    }
}

fn cfg(wl: WorkloadConfig, fw: Framework) -> ExperimentConfig {
    let mut c = ExperimentConfig::new(wl, fw);
    c.steps = STEPS;
    c
}

/// All regeneration goes through the typed Experiment builder — the
/// same single entry point the CLI and sweeps use.
fn evaluate(cfg: &ExperimentConfig, opts: &SimOptions) -> StepReport {
    Experiment::new(cfg.clone())
        .options(opts.clone())
        .build()
        .expect("preset configs resolve")
        .evaluate()
}

fn simulate(cfg: &ExperimentConfig, opts: &SimOptions) -> SimOutcome {
    Experiment::new(cfg.clone())
        .options(opts.clone())
        .build()
        .expect("preset configs resolve")
        .run()
}

fn main() {
    table2();
    fig7();
    fig1_and_89();
    fig10();
    fig11();
    table3();
    table4();
    println!("\nall paper artifacts regenerated — see EXPERIMENTS.md for the recorded comparison");
}

fn table2() {
    println!("== Table 2: overall training performance ==");
    let paper: &[(&str, &[(f64, f64, f64)])] = &[
        (
            "MA",
            &[
                (914.4, 1.0, 119.0),
                (293.8, 3.1, 401.0),
                (174.1, 5.3, 642.8),
                (126.1, 7.3, 910.2),
            ],
        ),
        (
            "CA",
            &[
                (438.6, 1.0, 265.5),
                (130.0, 3.4, 571.6),
                (112.8, 3.9, 655.9),
                (78.8, 5.6, 821.4),
            ],
        ),
    ];
    for (wl_name, paper_rows) in paper {
        let wl = if *wl_name == "MA" { WorkloadConfig::ma() } else { WorkloadConfig::ca() };
        let reports = sweep(&cfg(wl, Framework::flexmarl()), &opts());
        let rows = table_rows(&reports);
        println!(
            "  {wl_name}:  {:<10} {:>22} {:>26}",
            "framework", "paper (e2e/x/tps)", "ours (e2e/x/tps)"
        );
        for (r, p) in rows.iter().zip(*paper_rows) {
            println!(
                "       {:<10} {:>8.1}s {:>4.1}x {:>7.1}tps   {:>8.1}s {:>4.1}x {:>7.1}tps",
                r.framework, p.0, p.1, p.2, r.e2e_s, r.speedup, r.throughput_tps
            );
        }
    }
}

fn fig7() {
    println!("\n== Fig 7: E2E time breakdown (rollout / training / other) ==");
    for wl_name in ["MA", "CA"] {
        let wl = if wl_name == "MA" { WorkloadConfig::ma() } else { WorkloadConfig::ca() };
        println!("  {wl_name}:");
        for r in sweep(&cfg(wl, Framework::flexmarl()), &opts()) {
            println!(
                "    {:<10} rollout {:>6.1}s  train {:>6.1}s  other {:>5.1}s",
                r.framework, r.rollout_s, r.train_s, r.other_s
            );
        }
    }
    println!("  paper anchor: DistRL MA training 155.9s vs FlexMARL 10.2s (tail only)");
}

fn fig1_and_89() {
    println!("\n== Fig 1(a): interaction-latency long tail (DistRL profiling setup) ==");
    let out = simulate(&cfg(WorkloadConfig::ma(), Framework::dist_rl()), &opts());
    let mut lats = out.reports[0].trajectory_latencies.clone();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for q in [0.5, 0.9, 0.99, 1.0] {
        let idx = ((lats.len() - 1) as f64 * q) as usize;
        println!("    p{:<3} {:>7.1}s", (q * 100.0) as u32, lats[idx]);
    }
    println!("    paper: max ≈ 170s (long-tail dominates collection)");

    println!("\n== Fig 1(b) + Figs 8/9: per-agent queue + processed load ==");
    for fw in [Framework::dist_rl(), Framework::marti(), Framework::flexmarl()] {
        let out = simulate(&cfg(WorkloadConfig::ma(), fw), &opts());
        print!("    {:<10}", fw.name);
        for (a, series) in &out.series.processed {
            let total = series.last().map(|&(_, c)| c).unwrap_or(0);
            let t_done = series
                .iter()
                .find(|&&(_, c)| c == total && total > 0)
                .map(|&(t, _)| t)
                .unwrap_or(0.0);
            let peak_q = out.series.queued[a].iter().map(|&(_, q)| q).max().unwrap_or(0);
            print!("  a{a}: {total} req/{t_done:.0}s (peakQ {peak_q})");
        }
        println!();
    }
    println!("    paper: FlexMARL drains agent B in ~90s vs DistRL ~244s, MARTI ~159s");
}

fn fig10() {
    println!("\n== Fig 10: hardware utilization ==");
    println!("    paper CA: MAS-RL 3.6%  DistRL 10.2%  MARTI 12.3%  FlexMARL 19.8%");
    for wl_name in ["MA", "CA"] {
        let wl = if wl_name == "MA" { WorkloadConfig::ma() } else { WorkloadConfig::ca() };
        print!("    ours {wl_name}: ");
        for r in sweep(&cfg(wl, Framework::flexmarl()), &opts()) {
            print!(" {} {:.1}% ", r.framework, r.utilization() * 100.0);
        }
        println!();
    }
}

fn fig11() {
    println!("\n== Fig 11: training-state swap overhead ==");
    println!("    paper: offload 0.5s (3B) → 3.8s (32B); suspend/resume ~constant; total ≤ 11s");
    let c = ClusterConfig::default();
    for m in [ModelScale::B3, ModelScale::B7, ModelScale::B14, ModelScale::B32] {
        let o = swap_out_cost(m, &c);
        let i = swap_in_cost(m, &c, true);
        println!(
            "    {:>3}B  suspend {:.2}s offload {:.2}s | resume {:.2}s onload {:.2}s | total {:.1}s",
            m.params_b as u32,
            o.control_s,
            o.transfer_s,
            i.control_s,
            i.transfer_s,
            o.total() + i.total()
        );
    }
}

fn table3() {
    println!("\n== Table 3: ablations ==");
    println!(
        "    paper MA: w/o balancing 152.2s (6.0x)  w/o async 256.2s (3.6x)  full 126.1s (7.3x)"
    );
    for wl_name in ["MA", "CA"] {
        let wl = if wl_name == "MA" { WorkloadConfig::ma() } else { WorkloadConfig::ca() };
        let mas = evaluate(&cfg(wl.clone(), Framework::mas_rl()), &opts());
        print!("    ours {wl_name}:");
        for fw in [
            Framework::flexmarl_no_balancing(),
            Framework::flexmarl_no_async(),
            Framework::flexmarl(),
        ] {
            let r = evaluate(&cfg(wl.clone(), fw), &opts());
            print!("  {} {:.1}s ({:.1}x)", fw.name, r.e2e_s, mas.e2e_s / r.e2e_s);
        }
        println!();
    }
}

fn table4() {
    println!("\n== Table 4: heterogeneous scalability (FlexMARL) ==");
    println!(
        "    paper: 5x32B 160.3s/265.9tps | 3x32B+7x14B 132.5s/334.8tps | 15x14B 41.9s/754.2tps"
    );
    for spec in [
        vec![(5usize, ModelScale::B32)],
        vec![(3, ModelScale::B32), (7, ModelScale::B14)],
        vec![(15, ModelScale::B14)],
    ] {
        let wl = WorkloadConfig::scale_config(&spec);
        let name = wl.name.clone();
        let r = evaluate(&cfg(wl, Framework::flexmarl()), &opts());
        println!(
            "    ours {name}: rollout {:.1}s train {:.1}s e2e {:.1}s {:.1}tps",
            r.rollout_s,
            r.train_s,
            r.e2e_s,
            r.throughput_tps()
        );
    }
}
